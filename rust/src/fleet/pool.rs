//! The device pool: N heterogeneous simulated devices, each its own
//! serving engine.
//!
//! Every replica wraps one [`SimBackend`] in one
//! [`InferenceEngine`] with a single executor — one engine per modeled
//! phone/GPU, not one engine with many threads — so per-replica queue
//! depth and per-replica cost stay meaningful to the dispatcher. Route
//! resolution is a single warm-started pass over the whole fleet:
//! devices the tunedb store covers load from disk, the rest cold-tune
//! in one [`tune_layers_warm`] call, and the caller decides whether to
//! merge the fresh entries back to disk.

use anyhow::{Context, Result};

use super::spec::FleetSpec;
use crate::autotune::{tune_layers_warm, WarmStats};
use crate::coordinator::{InferenceEngine, RoutingTable, SimBackend};
use crate::simulator::DeviceConfig;
use crate::tunedb::TuneStore;
use crate::workload::NetworkDef;

/// One simulated device in the fleet, with its serving engine and the
/// two costs the dispatcher needs.
pub struct PoolReplica {
    /// `device#idx`, unique within the pool.
    pub label: String,
    pub device_name: String,
    /// Fingerprint of the device spec (ties BENCH rows to the tunedb).
    pub fingerprint: u64,
    pub engine: InferenceEngine<SimBackend>,
    /// Actual simulated time one request occupies this device (ms).
    pub sim_ms: f64,
    /// The dispatch cost signal: the routes' expected per-pass time
    /// ([`RoutingTable::expected_network_ms_for`]); falls back to
    /// `sim_ms` when the table carries no finite cost (uniform
    /// baselines).
    pub cost_ms: f64,
}

/// A started fleet: replicas in spec order, ready to serve.
pub struct DevicePool {
    replicas: Vec<PoolReplica>,
    queue_depth: usize,
    network: String,
    input_shape: Vec<usize>,
}

/// Resolve per-device routing tables for a whole fleet in one pass:
/// warm keys load from `store`, misses cold-tune (one
/// [`tune_layers_warm`] call over every fleet device) and are merged
/// into `store` — the caller persists the store if it wants the
/// cold-tune to stick.
pub fn resolve_routes(
    spec: &FleetSpec,
    net: &NetworkDef,
    store: &mut TuneStore,
    threads: usize,
) -> Result<(Vec<(DeviceConfig, RoutingTable)>, WarmStats)> {
    let devices = spec.devices();
    let (_, warm) = tune_layers_warm(&devices, &net.classes(), threads, store);
    let mut tables = Vec::with_capacity(devices.len());
    for dev in devices {
        let table = RoutingTable::from_store(store, &dev)
            .filter(|t| t.covers(net))
            .with_context(|| {
                format!("no routes covering {} for {} after tuning", net.name, dev.name)
            })?;
        tables.push((dev, table));
    }
    Ok((tables, warm))
}

impl DevicePool {
    /// Resolve routes for the fleet (warm-start from `store`, cold-tune
    /// misses in one pass) and start every replica's engine. The warm
    /// stats tell the caller whether the store gained entries worth
    /// persisting.
    pub fn start(
        spec: &FleetSpec,
        net: &NetworkDef,
        store: &mut TuneStore,
        threads: usize,
        queue_depth: usize,
    ) -> Result<(DevicePool, WarmStats)> {
        let (tables, warm) = resolve_routes(spec, net, store, threads)?;
        let with_replicas: Vec<(DeviceConfig, usize, RoutingTable)> = spec
            .entries
            .iter()
            .zip(tables)
            .map(|(e, (dev, table))| (dev, e.replicas, table))
            .collect();
        Ok((Self::start_with_tables(&with_replicas, net, queue_depth)?, warm))
    }

    /// Start a fleet from explicit `(device, replicas, routes)` triples
    /// — the injection point for tests and for callers that resolved
    /// routes themselves.
    pub fn start_with_tables(
        entries: &[(DeviceConfig, usize, RoutingTable)],
        net: &NetworkDef,
        queue_depth: usize,
    ) -> Result<DevicePool> {
        anyhow::ensure!(!entries.is_empty(), "fleet needs at least one device");
        anyhow::ensure!(queue_depth >= 1, "fleet queue depth must be at least 1");
        let mut replicas = Vec::new();
        let mut input_shape = Vec::new();
        for (dev, count, table) in entries {
            for idx in 0..*count {
                // pacing (time_scale) stays 0: the fleet driver runs a
                // virtual clock of its own, so wall-clock sleeps would
                // only slow the host without changing any reported
                // number
                let backend = SimBackend::new(dev, table, net, 0.0)
                    .with_context(|| format!("fleet replica {}#{idx}", dev.name))?;
                let sim_ms = backend.network_ms();
                anyhow::ensure!(
                    sim_ms > 0.0,
                    "{}: simulated pass priced at {sim_ms} ms",
                    dev.name
                );
                let route_ms = table.expected_network_ms_for(net);
                let cost_ms =
                    if route_ms.is_finite() && route_ms > 0.0 { route_ms } else { sim_ms };
                input_shape = backend.input_shape();
                let engine = InferenceEngine::start(backend, 1, queue_depth)
                    .with_context(|| format!("start engine for {}#{idx}", dev.name))?;
                replicas.push(PoolReplica {
                    label: format!("{}#{idx}", dev.name),
                    device_name: dev.name.to_string(),
                    fingerprint: dev.fingerprint(),
                    engine,
                    sim_ms,
                    cost_ms,
                });
            }
        }
        Ok(DevicePool { replicas, queue_depth, network: net.name.clone(), input_shape })
    }

    pub fn replicas(&self) -> &[PoolReplica] {
        &self.replicas
    }

    /// Per-replica bounded queue depth (backpressure/admission cap).
    pub fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    pub fn network(&self) -> &str {
        &self.network
    }

    /// The image shape fleet requests must carry.
    pub fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    /// Aggregate service capacity: requests/second the fleet sustains
    /// with every device busy (`Σ 1000 / sim_ms`). The yardstick
    /// open-loop arrival rates are set against.
    pub fn capacity_rps(&self) -> f64 {
        self.replicas.iter().map(|r| 1e3 / r.sim_ms).sum()
    }

    /// Drain and join every replica engine.
    pub fn shutdown(self) {
        for r in self.replicas {
            r.engine.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::convgen::Algorithm;

    fn quick_pool() -> DevicePool {
        let net = NetworkDef::by_name("resnet18").unwrap();
        let classes = net.classes();
        let mali = DeviceConfig::mali_g76_mp10();
        let vega = DeviceConfig::vega8();
        let entries = vec![
            (mali, 2, RoutingTable::uniform_for(Algorithm::Direct, &classes).unwrap()),
            (vega, 1, RoutingTable::uniform_for(Algorithm::Direct, &classes).unwrap()),
        ];
        DevicePool::start_with_tables(&entries, &net, 4).expect("pool")
    }

    #[test]
    fn pool_builds_one_replica_per_count_with_costs() {
        let pool = quick_pool();
        let labels: Vec<&str> = pool.replicas().iter().map(|r| r.label.as_str()).collect();
        assert_eq!(labels, vec!["Mali-G76 MP10#0", "Mali-G76 MP10#1", "Vega 8#0"]);
        for r in pool.replicas() {
            assert!(r.sim_ms > 0.0);
            // uniform tables carry no measured cost: the dispatch
            // signal falls back to the simulated pass time
            assert_eq!(r.cost_ms, r.sim_ms, "{}", r.label);
        }
        // identical replicas price identically; the integrated GPU is
        // faster than the mobile one
        assert_eq!(pool.replicas()[0].sim_ms, pool.replicas()[1].sim_ms);
        assert!(pool.replicas()[2].sim_ms < pool.replicas()[0].sim_ms);
        assert!(pool.capacity_rps() > 0.0);
        assert_eq!(pool.network(), "resnet18");
        pool.shutdown();
    }

    #[test]
    fn empty_fleet_and_partial_routes_are_rejected() {
        let net = NetworkDef::by_name("resnet18").unwrap();
        assert!(DevicePool::start_with_tables(&[], &net, 4).is_err());
        // a table missing a class must fail pool startup, not serve a
        // partly-priced network
        let mut partial = RoutingTable::default();
        partial.set(crate::workload::LayerClass::Conv2x, Algorithm::Ilpm, 1.0);
        let entries = vec![(DeviceConfig::vega8(), 1, partial)];
        assert!(DevicePool::start_with_tables(&entries, &net, 4).is_err());
    }
}
