//! The device pool: N heterogeneous simulated devices, engine-backed or
//! virtual.
//!
//! **Engine-backed** pools (the `serve --fleet` path) wrap one
//! [`SimBackend`] in one [`InferenceEngine`] with a single executor per
//! replica — one engine per modeled phone/GPU, not one engine with many
//! threads — so per-replica queue depth and per-replica cost stay
//! meaningful to the dispatcher. Each replica owns an executor thread,
//! so engine-backed fleets cap at [`MAX_ENGINE_REPLICAS`].
//!
//! **Virtual** pools ([`DevicePool::start_virtual`], the
//! `bench fleet-scale` path) carry the same labels, costs and plans but
//! no engines: the discrete-event driver prices everything on the
//! virtual clock, so thousands of replicas cost a few scalars each —
//! the device model is priced *once per device model* and shared, which
//! is what lets a 4096-replica pool start in milliseconds.
//!
//! Route resolution is a single warm-started pass over the whole fleet:
//! devices the tunedb store covers load from disk, the rest cold-tune
//! in one [`tune_layers_warm`] call, and the caller decides whether to
//! merge the fresh entries back to disk. Per-replica strings are
//! interned once (`Arc<str>`) and shared by every report row — the old
//! per-row `String::clone` fan-out is gone.

use std::sync::Arc;

use anyhow::{Context, Result};

use super::spec::FleetSpec;
use crate::autotune::{tune_layers_warm, WarmStats};
use crate::coordinator::{InferenceEngine, PlannedLayer, RoutingTable, SimBackend};
use crate::simulator::DeviceConfig;
use crate::tunedb::TuneStore;
use crate::workload::NetworkDef;

/// Hard cap on replicas in an *engine-backed* pool — each replica owns
/// an executor thread, and a typo like `mali:20000` should fail pool
/// start, not exhaust the host. Virtual pools (no threads) go far
/// beyond this; their cap is [`super::spec::MAX_REPLICAS`].
pub const MAX_ENGINE_REPLICAS: usize = 256;

/// One simulated device in the fleet, with the costs the dispatcher
/// needs and (for engine-backed pools) its serving engine.
pub struct PoolReplica {
    /// `device#idx`, unique within the pool. Interned: report rows
    /// share this allocation instead of cloning the string.
    pub label: Arc<str>,
    /// Device model name, shared by every replica of the model.
    pub device_name: Arc<str>,
    /// Fingerprint of the device spec (ties BENCH rows to the tunedb).
    pub fingerprint: u64,
    /// The host-side serving engine; `None` in virtual pools.
    pub engine: Option<InferenceEngine<SimBackend>>,
    /// Actual simulated time one request occupies this device (ms).
    pub sim_ms: f64,
    /// The dispatch cost signal: the routes' expected per-pass time
    /// (the dense route table's precomputed sum); falls back to
    /// `sim_ms` when the table carries no finite cost (uniform
    /// baselines).
    pub cost_ms: f64,
    /// The priced per-layer plan, shared by every replica of the
    /// device model — trace phase registration and algorithm-mix
    /// metrics read this, engine or no engine.
    pub plan: Arc<[PlannedLayer]>,
}

/// A started fleet: replicas in spec order, ready to serve.
pub struct DevicePool {
    replicas: Vec<PoolReplica>,
    queue_depth: usize,
    network: String,
    input_shape: Vec<usize>,
}

/// Resolve per-device routing tables for a whole fleet in one pass:
/// warm keys load from `store`, misses cold-tune (one
/// [`tune_layers_warm`] call over every fleet device) and are merged
/// into `store` — the caller persists the store if it wants the
/// cold-tune to stick. Tables come back aligned with `spec.entries`
/// (no device configs are cloned into the result).
pub fn resolve_routes(
    spec: &FleetSpec,
    net: &NetworkDef,
    store: &mut TuneStore,
    threads: usize,
) -> Result<(Vec<RoutingTable>, WarmStats)> {
    // the tuner wants an owned slice; this is the one place the fleet
    // copies device configs, once per device *model* per run
    let devices: Vec<DeviceConfig> = spec.devices().into_iter().cloned().collect();
    let (_, warm) = tune_layers_warm(&devices, &net.classes(), threads, store);
    let mut tables = Vec::with_capacity(devices.len());
    for dev in &devices {
        let table = RoutingTable::from_store(store, dev)
            .filter(|t| t.covers(net))
            .with_context(|| {
                format!("no routes covering {} for {} after tuning", net.name, dev.name)
            })?;
        tables.push(table);
    }
    Ok((tables, warm))
}

impl DevicePool {
    /// Resolve routes for the fleet (warm-start from `store`, cold-tune
    /// misses in one pass) and start every replica's engine. The warm
    /// stats tell the caller whether the store gained entries worth
    /// persisting.
    pub fn start(
        spec: &FleetSpec,
        net: &NetworkDef,
        store: &mut TuneStore,
        threads: usize,
        queue_depth: usize,
    ) -> Result<(DevicePool, WarmStats)> {
        let (tables, warm) = resolve_routes(spec, net, store, threads)?;
        let entries: Vec<(&DeviceConfig, usize, &RoutingTable)> = spec
            .entries
            .iter()
            .zip(&tables)
            .map(|(e, table)| (&e.device, e.replicas, table))
            .collect();
        Ok((Self::build(&entries, net, queue_depth, true)?, warm))
    }

    /// [`DevicePool::start`] without engines: same routes, labels and
    /// costs, no executor threads — the pool `bench fleet-scale` drives
    /// at thousands of replicas.
    pub fn start_virtual(
        spec: &FleetSpec,
        net: &NetworkDef,
        store: &mut TuneStore,
        threads: usize,
        queue_depth: usize,
    ) -> Result<(DevicePool, WarmStats)> {
        let (tables, warm) = resolve_routes(spec, net, store, threads)?;
        let entries: Vec<(&DeviceConfig, usize, &RoutingTable)> = spec
            .entries
            .iter()
            .zip(&tables)
            .map(|(e, table)| (&e.device, e.replicas, table))
            .collect();
        Ok((Self::build(&entries, net, queue_depth, false)?, warm))
    }

    /// Start an engine-backed fleet from explicit
    /// `(device, replicas, routes)` triples — the injection point for
    /// tests and for callers that resolved routes themselves.
    pub fn start_with_tables(
        entries: &[(DeviceConfig, usize, RoutingTable)],
        net: &NetworkDef,
        queue_depth: usize,
    ) -> Result<DevicePool> {
        let refs: Vec<(&DeviceConfig, usize, &RoutingTable)> =
            entries.iter().map(|(d, n, t)| (d, *n, t)).collect();
        Self::build(&refs, net, queue_depth, true)
    }

    /// [`DevicePool::start_with_tables`] without engines.
    pub fn start_virtual_with_tables(
        entries: &[(DeviceConfig, usize, RoutingTable)],
        net: &NetworkDef,
        queue_depth: usize,
    ) -> Result<DevicePool> {
        let refs: Vec<(&DeviceConfig, usize, &RoutingTable)> =
            entries.iter().map(|(d, n, t)| (d, *n, t)).collect();
        Self::build(&refs, net, queue_depth, false)
    }

    fn build(
        entries: &[(&DeviceConfig, usize, &RoutingTable)],
        net: &NetworkDef,
        queue_depth: usize,
        engines: bool,
    ) -> Result<DevicePool> {
        anyhow::ensure!(!entries.is_empty(), "fleet needs at least one device");
        anyhow::ensure!(queue_depth >= 1, "fleet queue depth must be at least 1");
        let total: usize = entries.iter().map(|(_, count, _)| count).sum();
        if engines {
            anyhow::ensure!(
                total <= MAX_ENGINE_REPLICAS,
                "{total} replicas, but engine-backed fleets cap at {MAX_ENGINE_REPLICAS} \
                 (one executor thread each) — larger fleets serve virtually \
                 (`bench fleet-scale`)",
            );
        }
        let mut replicas = Vec::with_capacity(total);
        let mut input_shape = Vec::new();
        for (dev, count, table) in entries {
            // price the device model once; every replica of the model
            // shares the plan, the costs and the interned name
            let reference = SimBackend::new(dev, table, net, 0.0)
                .with_context(|| format!("fleet device {}", dev.name))?;
            let sim_ms = reference.network_ms();
            anyhow::ensure!(sim_ms > 0.0, "{}: simulated pass priced at {sim_ms} ms", dev.name);
            // the dense table's precomputed pass cost — same sum, no
            // per-layer hashing at serve time
            let dense = table.dense_for(net).expect("SimBackend::new verified coverage");
            let route_ms = dense.expected_pass_ms();
            let cost_ms = if route_ms.is_finite() && route_ms > 0.0 { route_ms } else { sim_ms };
            input_shape = reference.input_shape();
            let plan: Arc<[PlannedLayer]> = reference.plan().to_vec().into();
            let device_name: Arc<str> = Arc::from(dev.name);
            let fingerprint = dev.fingerprint();
            // the pricing backend doubles as replica 0's engine backend
            let mut spare = Some(reference);
            for idx in 0..*count {
                let engine = if engines {
                    // pacing (time_scale) stays 0: the fleet driver
                    // runs a virtual clock of its own, so wall-clock
                    // sleeps would only slow the host without changing
                    // any reported number
                    let backend = match spare.take() {
                        Some(b) => b,
                        None => SimBackend::new(dev, table, net, 0.0)
                            .with_context(|| format!("fleet replica {}#{idx}", dev.name))?,
                    };
                    Some(
                        InferenceEngine::start(backend, 1, queue_depth)
                            .with_context(|| format!("start engine for {}#{idx}", dev.name))?,
                    )
                } else {
                    None
                };
                replicas.push(PoolReplica {
                    label: format!("{}#{idx}", dev.name).into(),
                    device_name: Arc::clone(&device_name),
                    fingerprint,
                    engine,
                    sim_ms,
                    cost_ms,
                    plan: Arc::clone(&plan),
                });
            }
        }
        Ok(DevicePool { replicas, queue_depth, network: net.name.clone(), input_shape })
    }

    pub fn replicas(&self) -> &[PoolReplica] {
        &self.replicas
    }

    /// Per-replica bounded queue depth (backpressure/admission cap).
    pub fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    pub fn network(&self) -> &str {
        &self.network
    }

    /// The image shape fleet requests must carry.
    pub fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    /// True when the pool carries no engines (virtual-clock only).
    pub fn is_virtual(&self) -> bool {
        self.replicas.iter().all(|r| r.engine.is_none())
    }

    /// Aggregate service capacity: requests/second the fleet sustains
    /// with every device busy (`Σ 1000 / sim_ms`). The yardstick
    /// open-loop arrival rates are set against.
    pub fn capacity_rps(&self) -> f64 {
        self.replicas.iter().map(|r| 1e3 / r.sim_ms).sum()
    }

    /// Drain and join every replica engine.
    pub fn shutdown(self) {
        for r in self.replicas {
            if let Some(engine) = r.engine {
                engine.shutdown();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::convgen::Algorithm;

    fn entries() -> Vec<(DeviceConfig, usize, RoutingTable)> {
        let classes = NetworkDef::by_name("resnet18").unwrap().classes();
        let mali = DeviceConfig::mali_g76_mp10();
        let vega = DeviceConfig::vega8();
        vec![
            (mali, 2, RoutingTable::uniform_for(Algorithm::Direct, &classes).unwrap()),
            (vega, 1, RoutingTable::uniform_for(Algorithm::Direct, &classes).unwrap()),
        ]
    }

    fn quick_pool() -> DevicePool {
        let net = NetworkDef::by_name("resnet18").unwrap();
        DevicePool::start_with_tables(&entries(), &net, 4).expect("pool")
    }

    #[test]
    fn pool_builds_one_replica_per_count_with_costs() {
        let pool = quick_pool();
        let labels: Vec<&str> = pool.replicas().iter().map(|r| &*r.label).collect();
        assert_eq!(labels, vec!["Mali-G76 MP10#0", "Mali-G76 MP10#1", "Vega 8#0"]);
        for r in pool.replicas() {
            assert!(r.sim_ms > 0.0);
            // uniform tables carry no measured cost: the dispatch
            // signal falls back to the simulated pass time
            assert_eq!(r.cost_ms, r.sim_ms, "{}", r.label);
            assert!(!r.plan.is_empty());
        }
        // identical replicas price identically; the integrated GPU is
        // faster than the mobile one
        assert_eq!(pool.replicas()[0].sim_ms, pool.replicas()[1].sim_ms);
        assert!(pool.replicas()[2].sim_ms < pool.replicas()[0].sim_ms);
        assert!(pool.capacity_rps() > 0.0);
        assert_eq!(pool.network(), "resnet18");
        assert!(!pool.is_virtual());
        pool.shutdown();
    }

    #[test]
    fn replicas_of_one_model_share_interned_strings_and_plan() {
        let pool = quick_pool();
        let (a, b) = (&pool.replicas()[0], &pool.replicas()[1]);
        assert!(Arc::ptr_eq(&a.device_name, &b.device_name), "device name must be interned");
        assert!(Arc::ptr_eq(&a.plan, &b.plan), "plan must be shared, not re-priced");
        pool.shutdown();
    }

    #[test]
    fn virtual_pool_matches_engine_pool_pricing_without_engines() {
        let net = NetworkDef::by_name("resnet18").unwrap();
        let engine_pool = quick_pool();
        let virt = DevicePool::start_virtual_with_tables(&entries(), &net, 4).expect("virtual");
        assert!(virt.is_virtual());
        assert_eq!(virt.replicas().len(), engine_pool.replicas().len());
        for (v, e) in virt.replicas().iter().zip(engine_pool.replicas()) {
            assert_eq!(v.label, e.label);
            assert_eq!(v.sim_ms, e.sim_ms, "{}", v.label);
            assert_eq!(v.cost_ms, e.cost_ms, "{}", v.label);
            assert!(v.engine.is_none());
        }
        assert_eq!(virt.input_shape(), engine_pool.input_shape());
        engine_pool.shutdown();
        virt.shutdown();
    }

    #[test]
    fn virtual_pools_scale_past_the_engine_cap() {
        let net = NetworkDef::by_name("resnet18").unwrap();
        let classes = net.classes();
        let big = vec![(
            DeviceConfig::vega8(),
            4 * MAX_ENGINE_REPLICAS,
            RoutingTable::uniform_for(Algorithm::Direct, &classes).unwrap(),
        )];
        // engine-backed: rejected, with a pointer at the virtual path
        let err = DevicePool::start_with_tables(&big, &net, 4).unwrap_err();
        assert!(err.to_string().contains("fleet-scale"), "{err:#}");
        // virtual: fine, and priced once per model
        let pool = DevicePool::start_virtual_with_tables(&big, &net, 4).expect("virtual pool");
        assert_eq!(pool.replicas().len(), 4 * MAX_ENGINE_REPLICAS);
        let first = &pool.replicas()[0];
        assert!(pool.replicas().iter().all(|r| r.sim_ms == first.sim_ms));
        pool.shutdown();
    }

    #[test]
    fn empty_fleet_and_partial_routes_are_rejected() {
        let net = NetworkDef::by_name("resnet18").unwrap();
        assert!(DevicePool::start_with_tables(&[], &net, 4).is_err());
        // a table missing a class must fail pool startup, not serve a
        // partly-priced network
        let mut partial = RoutingTable::default();
        partial.set(crate::workload::LayerClass::Conv2x, Algorithm::Ilpm, 1.0);
        let entries = vec![(DeviceConfig::vega8(), 1, partial)];
        assert!(DevicePool::start_with_tables(&entries, &net, 4).is_err());
    }
}
