//! Fleet — heterogeneous multi-device serving above the engine.
//!
//! The paper tunes per-layer algorithm routes *per device* because
//! mobile GPUs differ wildly; this module is the layer the ROADMAP's
//! "serve heavy traffic" north star demands above that: many simulated
//! devices ([`DevicePool`] — each replica its own
//! [`crate::coordinator::InferenceEngine`] over a
//! [`crate::coordinator::SimBackend`], routes resolved per device from
//! the tunedb store in one warm-started pass), an open-loop traffic
//! generator (Poisson / burst arrivals from
//! [`crate::workload::TraceKind`]), pluggable [`DispatchPolicy`]s
//! culminating in `cost-aware` — which spends the tuner's per-device
//! route costs as a load-balancing signal — and SLO machinery
//! ([`SloConfig`]: per-request deadlines with admission control that
//! sheds predicted-late work, sheds and violations ledgered separately
//! in the [`FleetReport`]).
//!
//! CLI front doors: `ilpm serve --fleet mali:2,vega8:1 --policy
//! cost-aware …` and `ilpm bench fleet` (BENCH_fleet.json with the
//! `cost_aware_beats_round_robin` verdict). See DESIGN.md "Fleet
//! serving" for the dispatch-policy table and the admission-control
//! formula.

mod dispatch;
mod pool;
mod serve;
mod spec;

pub use dispatch::{DispatchPolicy, ReplicaView};
pub use pool::{resolve_routes, DevicePool, PoolReplica};
pub use serve::{
    run_open_loop, run_open_loop_traced, FleetReport, OpenLoopConfig, ReplicaReport, SloConfig,
};
pub use spec::{FleetEntry, FleetSpec, MAX_REPLICAS};
