//! Fleet — heterogeneous multi-device serving above the engine.
//!
//! The paper tunes per-layer algorithm routes *per device* because
//! mobile GPUs differ wildly; this module is the layer the ROADMAP's
//! "serve heavy traffic" north star demands above that: many simulated
//! devices ([`DevicePool`] — replicas priced once per device model,
//! engine-backed replicas each owning their own
//! [`crate::coordinator::InferenceEngine`] over a
//! [`crate::coordinator::SimBackend`], routes resolved per device from
//! the tunedb store in one warm-started pass), an open-loop traffic
//! generator (Poisson / burst arrivals from
//! [`crate::workload::TraceKind`]), pluggable [`DispatchPolicy`]s
//! culminating in `cost-aware` — which spends the tuner's per-device
//! route costs as a load-balancing signal — and SLO machinery
//! ([`SloConfig`]: per-request deadlines with admission control that
//! sheds predicted-late work, sheds and violations ledgered separately
//! in the [`FleetReport`]).
//!
//! Serving is a discrete-event simulation: a binary-heap
//! [`EventQueue`] (module [`events`]) drives arrivals and completions
//! in deterministic order, replicas are passive dense state the
//! dispatcher reads through a borrowed [`FleetView`], and the
//! per-request hot path allocates nothing. Engine-backed pools are
//! capped at [`MAX_ENGINE_REPLICAS`] (each replica is a live thread
//! pool); *virtual* pools ([`DevicePool::start_virtual`]) drop the
//! engines and scale to [`MAX_REPLICAS`] replicas — the `ilpm bench
//! fleet-scale` path pushes 4096 replicas through a million requests
//! in seconds, byte-identical from the seed.
//!
//! Time-resolved visibility comes from the [`FlightRecorder`]
//! ([`run_open_loop_recorded`]): a fixed-capacity
//! [`crate::trace::TimelineSampler`] closes telemetry windows on
//! `Sample` events (ranked after every same-instant event, so sampling
//! never perturbs dispatch) and a [`crate::trace::BurnRateMonitor`]
//! raises deterministic SLO burn-rate alerts, ledgered in
//! [`FleetReport::alerts`].
//!
//! CLI front doors: `ilpm serve --fleet mali:2,vega8:1 --policy
//! cost-aware …` (`--timeline PATH --sample-ms N` for the flight
//! recorder), `ilpm monitor --timeline PATH` (text dashboard), `ilpm
//! bench fleet` (BENCH_fleet.json with the
//! `cost_aware_beats_round_robin` verdict), `ilpm bench fleet-scale`
//! (BENCH_fleet_scale.json), and `ilpm bench monitor`
//! (BENCH_monitor.json). See DESIGN.md "Fleet serving" for the event
//! taxonomy, dispatch-policy table, and the admission-control formula,
//! and the Observability section for window/burn-rate semantics.

mod dispatch;
mod events;
#[cfg(test)]
mod legacy;
mod pool;
mod serve;
mod spec;

pub use dispatch::{DispatchPolicy, FleetView};
pub use events::{Event, EventKind, EventQueue};
pub use pool::{resolve_routes, DevicePool, PoolReplica, MAX_ENGINE_REPLICAS};
pub use serve::{
    run_open_loop, run_open_loop_recorded, run_open_loop_traced, FleetReport, FlightRecorder,
    OpenLoopConfig, ReplicaReport, SloConfig,
};
pub use spec::{FleetEntry, FleetSpec, MAX_REPLICAS};
