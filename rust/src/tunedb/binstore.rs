//! Append-only binary tunedb segment file.
//!
//! Layout: one [`record::CELL`]-byte header block, then uniformly-sized
//! cells — tuning records, and (after sealing) a footer of index cells
//! plus one trailer cell. Block 0 is the header; cell *k* is block *k*.
//!
//! * **Appends are raw `O_APPEND` record writes** — a tuner merging
//!   results back never reads, rewrites, or locks anything another
//!   writer appended, which is why concurrent merge-back cannot lose
//!   entries the way the JSON store's read-modify-write can.
//! * **Later records supersede earlier ones at load** (same
//!   `(fingerprint, layer, algorithm)` key), so appending is also how
//!   entries are updated. [`compact`] drops the superseded bodies.
//! * **The footer is advisory.** A file whose *last complete cell* is a
//!   valid trailer is *sealed*: [`load_device`] seeks straight to one
//!   fingerprint's records (header + footer + that device's cells, and
//!   nothing else). Appending after a seal simply un-seals the file —
//!   the trailer is no longer last, readers notice and fall back to a
//!   full scan, and the stale footer cells are skipped by tag.
//!   [`seal`] appends a fresh footer; it never rewrites data.
//! * **Corruption is contained.** A torn tail (partial final cell) is
//!   skipped with a warning; a cell with a bad checksum is skipped with
//!   a warning; wrong magic/version/endianness is a clean error. A load
//!   therefore never panics and never yields a record that did not pass
//!   its checksum.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::{Context, Result};

use super::record::{self, Cell};
use super::store::{StoredTuning, TuneStore};
use crate::workload::LayerClass;

pub use super::record::{BIN_SCHEMA_VERSION, CELL, ENDIAN_PROBE, INDEX_FANOUT, MAGIC};

/// What a load saw: cell accounting, repair warnings, and the bytes the
/// reader actually touched (the routeload bench's read-amplification
/// metric; the counting-reader test cross-checks it).
#[derive(Debug, Clone, Default)]
pub struct LoadReport {
    /// Checksum-clean tuning records decoded (before supersede-merge).
    pub data_cells: usize,
    /// Index and trailer cells seen.
    pub footer_cells: usize,
    /// Damaged or unknown cells skipped.
    pub skipped: usize,
    /// Trailing bytes of a truncated final record, skipped.
    pub torn_tail_bytes: usize,
    /// True when the footer served the read (no full scan).
    pub indexed: bool,
    /// Bytes read from the underlying file.
    pub bytes_read: u64,
    pub warnings: Vec<String>,
}

/// Is the file at `path` a binary tunedb store? (Sniffs the magic;
/// missing or unreadable files are "no".)
pub fn is_binstore(path: &Path) -> bool {
    let mut buf = [0u8; 8];
    match File::open(path) {
        Ok(mut f) => f.read_exact(&mut buf).is_ok() && buf == MAGIC,
        Err(_) => false,
    }
}

/// Create an empty (header-only) store. Existing non-empty files are
/// left untouched.
pub fn create(path: &Path) -> Result<()> {
    append_cells(path, &[])
}

/// Append one tuning record. Creates the file (with its header) on
/// first use. The record lands in a single `O_APPEND` write, so
/// concurrent appenders to one pre-created store interleave whole
/// cells and never clobber each other.
pub fn append(path: &Path, fp: u64, device: &str, t: &StoredTuning) -> Result<()> {
    append_cells(path, &[record::encode_data(fp, device, t)?])
}

/// Append entries from an in-memory store for an explicit key list —
/// the tuner's merge-back: only the freshly tuned keys are written
/// (sorted, so identical runs append identical bytes), then the file is
/// re-sealed. Keys the store does not hold are ignored.
pub fn append_from_store(
    path: &Path,
    store: &TuneStore,
    keys: &[(u64, LayerClass, crate::convgen::Algorithm)],
) -> Result<usize> {
    let mut keys: Vec<_> = keys.to_vec();
    keys.sort_by(|a, b| (a.0, a.1.name(), a.2.name()).cmp(&(b.0, b.1.name(), b.2.name())));
    keys.dedup();
    let mut cells = Vec::new();
    for (fp, layer, alg) in keys {
        let Some(t) = store.get(fp, layer, alg) else { continue };
        let device = store.device(fp).map(|d| d.device.as_str()).unwrap_or("");
        cells.push(record::encode_data(fp, device, t)?);
    }
    let appended = cells.len();
    append_cells(path, &cells)?;
    seal(path)?;
    Ok(appended)
}

/// Persist a store to `path` in the format `path` uses (an existing
/// file is sniffed; a fresh `.tdb` path is binary, anything else JSON).
/// Binary merge-back is append-only: only `fresh` keys are written.
/// With no fresh keys an existing file is left byte-identical.
pub fn merge_back(
    store: &TuneStore,
    fresh: &[(u64, LayerClass, crate::convgen::Algorithm)],
    path: &Path,
) -> Result<()> {
    if !is_binary_path(path) {
        return store.save(path);
    }
    if !path.exists() {
        return write_sealed(store, path);
    }
    if !fresh.is_empty() {
        append_from_store(path, store, fresh)?;
    }
    Ok(())
}

/// Does `path` name a binary store? Existing files are sniffed by
/// magic; fresh paths choose by the `.tdb` extension.
pub fn is_binary_path(path: &Path) -> bool {
    if path.exists() {
        is_binstore(path)
    } else {
        path.extension().and_then(|e| e.to_str()) == Some("tdb")
    }
}

fn append_cells(path: &Path, cells: &[[u8; CELL]]) -> Result<()> {
    if let Some(dir) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
        std::fs::create_dir_all(dir).with_context(|| format!("create dir {}", dir.display()))?;
    }
    let len = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
    let mut buf = Vec::with_capacity(CELL * (cells.len() + 1));
    if len == 0 {
        buf.extend_from_slice(&record::header_block());
    } else if (len as usize) < CELL || (len as usize - CELL) % CELL != 0 {
        // torn tail from a crashed writer: appending after it would
        // shift every later cell off the 192-byte grid, so repair by
        // truncating the partial record before appending
        let aligned = if (len as usize) < CELL {
            0
        } else {
            len - ((len as usize - CELL) % CELL) as u64
        };
        let f = OpenOptions::new()
            .write(true)
            .open(path)
            .with_context(|| format!("open {} to repair torn tail", path.display()))?;
        f.set_len(aligned)
            .with_context(|| format!("truncate torn tail of {}", path.display()))?;
        if aligned == 0 {
            buf.extend_from_slice(&record::header_block());
        }
    }
    for c in cells {
        buf.extend_from_slice(c);
    }
    if buf.is_empty() {
        return Ok(());
    }
    let mut f = OpenOptions::new()
        .append(true)
        .create(true)
        .open(path)
        .with_context(|| format!("open {} for append", path.display()))?;
    f.write_all(&buf).with_context(|| format!("append to {}", path.display()))?;
    Ok(())
}

/// Load every record in the file (full scan; supersede-on-load).
pub fn load(path: &Path) -> Result<(TuneStore, LoadReport)> {
    let bytes =
        std::fs::read(path).with_context(|| format!("read tunedb {}", path.display()))?;
    load_bytes(&bytes).with_context(|| format!("parse tunedb {}", path.display()))
}

/// [`load`] over an in-memory image (the corruption fuzzer's entry
/// point — must return cleanly, never panic, on arbitrary bytes).
pub fn load_bytes(bytes: &[u8]) -> Result<(TuneStore, LoadReport)> {
    record::check_header(bytes)?;
    let mut rep = LoadReport { bytes_read: bytes.len() as u64, ..Default::default() };
    let body = &bytes[CELL..];
    rep.torn_tail_bytes = body.len() % CELL;
    if rep.torn_tail_bytes > 0 {
        rep.warnings.push(format!(
            "torn tail: {} trailing byte(s) of a truncated record skipped",
            rep.torn_tail_bytes
        ));
    }
    let mut store = TuneStore::new();
    for (i, cell) in body.chunks_exact(CELL).enumerate() {
        match record::decode(cell) {
            Ok(Cell::Data { fp, device, tuning }) => {
                store.insert(fp, &device, tuning);
                rep.data_cells += 1;
            }
            Ok(Cell::Index { .. }) | Ok(Cell::Trailer { .. }) => rep.footer_cells += 1,
            Err(e) => {
                rep.skipped += 1;
                rep.warnings.push(format!("cell {} (block {}): {e:#} — skipped", i, i + 1));
            }
        }
    }
    Ok((store, rep))
}

/// Load just one fingerprint's records. Sealed files are read via the
/// footer: header block, trailer, index cells, then exactly that
/// device's data cells — nothing else. Unsealed (or damaged-footer)
/// files fall back to a full scan.
pub fn load_device(path: &Path, fp: u64) -> Result<(TuneStore, LoadReport)> {
    let mut f =
        File::open(path).with_context(|| format!("open tunedb {}", path.display()))?;
    load_device_from(&mut f, fp).with_context(|| format!("read tunedb {}", path.display()))
}

/// [`load_device`] over any seekable reader (tests wrap a counting
/// reader around the file to assert exactly which bytes a serve-start
/// route load touches).
pub fn load_device_from<R: Read + Seek>(r: &mut R, fp: u64) -> Result<(TuneStore, LoadReport)> {
    let mut rep = LoadReport::default();
    let len = r.seek(SeekFrom::End(0))?;
    let mut cell = [0u8; CELL];
    r.seek(SeekFrom::Start(0))?;
    if len < CELL as u64 {
        let mut short = vec![0u8; len as usize];
        r.read_exact(&mut short)?;
        record::check_header(&short)?; // always errs usefully
        unreachable!("check_header accepts only full headers");
    }
    r.read_exact(&mut cell)?;
    rep.bytes_read += CELL as u64;
    record::check_header(&cell)?;

    let body = len - CELL as u64;
    rep.torn_tail_bytes = (body % CELL as u64) as usize;
    if rep.torn_tail_bytes > 0 {
        rep.warnings.push(format!(
            "torn tail: {} trailing byte(s) of a truncated record skipped",
            rep.torn_tail_bytes
        ));
    }
    let blocks = body / CELL as u64; // complete cells; block index of the last one
    if blocks == 0 {
        return Ok((TuneStore::new(), rep));
    }
    read_block(r, blocks, &mut cell)?;
    rep.bytes_read += CELL as u64;
    let footer = match record::decode(&cell) {
        Ok(Cell::Trailer { index_start, index_cells, .. })
            if index_start >= 1 && index_start + index_cells == blocks =>
        {
            Some((index_start, index_cells))
        }
        _ => None,
    };
    let Some((index_start, index_cells)) = footer else {
        rep.warnings
            .push("no valid footer at the tail (unsealed store) — full scan".to_string());
        return scan_for_device(r, fp, rep);
    };
    rep.indexed = true;
    rep.footer_cells = 1 + index_cells as usize;

    let mut offsets: Vec<u64> = Vec::new();
    r.seek(SeekFrom::Start(index_start * CELL as u64))?;
    for b in 0..index_cells {
        r.read_exact(&mut cell)?;
        rep.bytes_read += CELL as u64;
        match record::decode(&cell) {
            Ok(Cell::Index { fp: cell_fp, blocks: offs }) => {
                if cell_fp == fp {
                    offsets.extend(offs);
                }
            }
            _ => {
                // a footer that lies about its own cells cannot be
                // trusted about anyone's offsets
                rep.indexed = false;
                rep.warnings.push(format!(
                    "footer block {} is not a valid index cell — full scan",
                    index_start + b
                ));
                return scan_for_device(r, fp, rep);
            }
        }
    }
    offsets.sort_unstable();
    offsets.dedup();

    let mut store = TuneStore::new();
    for &b in &offsets {
        if b < 1 || b >= index_start {
            rep.skipped += 1;
            rep.warnings.push(format!("index points outside the data region (block {b})"));
            continue;
        }
        read_block(r, b, &mut cell)?;
        rep.bytes_read += CELL as u64;
        match record::decode(&cell) {
            Ok(Cell::Data { fp: cell_fp, device, tuning }) if cell_fp == fp => {
                store.insert(fp, &device, tuning);
                rep.data_cells += 1;
            }
            Ok(_) => {
                rep.skipped += 1;
                rep.warnings
                    .push(format!("block {b}: indexed cell is not this device's record"));
            }
            Err(e) => {
                rep.skipped += 1;
                rep.warnings.push(format!("block {b}: {e:#} — skipped"));
            }
        }
    }
    Ok((store, rep))
}

fn read_block<R: Read + Seek>(r: &mut R, block: u64, cell: &mut [u8; CELL]) -> Result<()> {
    r.seek(SeekFrom::Start(block * CELL as u64))?;
    r.read_exact(cell)?;
    Ok(())
}

fn scan_for_device<R: Read + Seek>(
    r: &mut R,
    fp: u64,
    mut rep: LoadReport,
) -> Result<(TuneStore, LoadReport)> {
    r.seek(SeekFrom::Start(0))?;
    let mut bytes = Vec::new();
    r.read_to_end(&mut bytes)?;
    let (full, scan_rep) = load_bytes(&bytes)?;
    rep.bytes_read += bytes.len() as u64;
    rep.data_cells = scan_rep.data_cells;
    rep.footer_cells = scan_rep.footer_cells;
    rep.skipped = scan_rep.skipped;
    rep.warnings.extend(scan_rep.warnings);
    let mut out = TuneStore::new();
    if let Some(d) = full.device(fp) {
        for t in d.entries() {
            out.insert(fp, &d.device, t.clone());
        }
    }
    Ok((out, rep))
}

/// The deterministic sealed image of a store: header, data cells sorted
/// by `(fingerprint, layer, algorithm)`, footer. Identical stores yield
/// identical bytes (same contract as `TuneStore::to_json`). Devices
/// with zero entries are not representable as records and are dropped.
pub fn sealed_bytes(store: &TuneStore) -> Result<Vec<u8>> {
    let mut devices: Vec<_> = store.devices().collect();
    devices.sort_by_key(|(fp, _)| *fp);
    let mut out = Vec::with_capacity(CELL * (store.len() + devices.len() + 2));
    out.extend_from_slice(&record::header_block());
    let mut index: Vec<(u64, Vec<u64>)> = Vec::new();
    let mut block = 1u64;
    for (fp, d) in devices {
        if d.is_empty() {
            continue;
        }
        let mut entries: Vec<&StoredTuning> = d.entries().collect();
        entries.sort_by_key(|t| (t.layer.name(), t.algorithm.name()));
        let mut blocks_for = Vec::with_capacity(entries.len());
        for t in entries {
            out.extend_from_slice(&record::encode_data(fp, &d.device, t)?);
            blocks_for.push(block);
            block += 1;
        }
        index.push((fp, blocks_for));
    }
    let index_start = block;
    let mut index_cells = 0u64;
    for (fp, blocks_for) in &index {
        for chunk in blocks_for.chunks(INDEX_FANOUT) {
            out.extend_from_slice(&record::encode_index(*fp, chunk));
            index_cells += 1;
        }
    }
    out.extend_from_slice(&record::encode_trailer(
        index_start,
        index_cells,
        index.len() as u64,
        index_start - 1,
    ));
    Ok(out)
}

/// Write a store as a fresh sealed file, atomically (temp + rename,
/// like the JSON store's save).
pub fn write_sealed(store: &TuneStore, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
        std::fs::create_dir_all(dir).with_context(|| format!("create dir {}", dir.display()))?;
    }
    let bytes = sealed_bytes(store)?;
    let stem = path.file_name().and_then(|s| s.to_str()).unwrap_or("tunedb.tdb");
    let tmp = path.with_file_name(format!(".{stem}.tmp.{}", std::process::id()));
    std::fs::write(&tmp, &bytes).with_context(|| format!("write {}", tmp.display()))?;
    std::fs::rename(&tmp, path).with_context(|| {
        let _ = std::fs::remove_file(&tmp);
        format!("rename {} -> {}", tmp.display(), path.display())
    })?;
    Ok(())
}

/// Append a footer (index cells + trailer) indexing every live data
/// cell currently in the file. Append-only: a previous footer's cells
/// stay in place as dead weight (skipped by tag on scan, dropped by
/// [`compact`]); only the new trailer, now last, is authoritative.
pub fn seal(path: &Path) -> Result<()> {
    let bytes =
        std::fs::read(path).with_context(|| format!("read tunedb {}", path.display()))?;
    record::check_header(&bytes)?;
    let body = &bytes[CELL..];
    let mut per_fp: Vec<(u64, Vec<u64>)> = Vec::new();
    for (i, cell) in body.chunks_exact(CELL).enumerate() {
        if let Ok(Cell::Data { fp, .. }) = record::decode(cell) {
            let block = (i + 1) as u64;
            match per_fp.iter_mut().find(|(f, _)| *f == fp) {
                Some((_, v)) => v.push(block),
                None => per_fp.push((fp, vec![block])),
            }
        }
    }
    per_fp.sort_by_key(|(fp, _)| *fp);
    let covered = (body.len() / CELL) as u64;
    let index_start = covered + 1;
    let mut cells: Vec<[u8; CELL]> = Vec::new();
    for (fp, blocks) in &per_fp {
        for chunk in blocks.chunks(INDEX_FANOUT) {
            cells.push(record::encode_index(*fp, chunk));
        }
    }
    cells.push(record::encode_trailer(
        index_start,
        cells.len() as u64,
        per_fp.len() as u64,
        covered,
    ));
    append_cells(path, &cells)
}

/// What [`compact`] did.
#[derive(Debug, Clone, Default)]
pub struct CompactReport {
    /// Cells (excluding the header) before and after.
    pub before_cells: u64,
    pub after_cells: u64,
    /// Superseded, damaged, and stale-footer cells dropped.
    pub dropped: u64,
    pub entries: usize,
    pub devices: usize,
    pub warnings: Vec<String>,
}

/// Rewrite the file as the minimal sealed image of its live entries:
/// superseded records, damaged cells, and stale footers are dropped,
/// and the footer is rebuilt. Load-equivalent to the input and
/// idempotent (a second compact is a byte-identical no-op).
pub fn compact(path: &Path) -> Result<CompactReport> {
    let before = std::fs::metadata(path)
        .with_context(|| format!("stat {}", path.display()))?
        .len();
    let (store, load_rep) = load(path)?;
    write_sealed(&store, path)?;
    let after = std::fs::metadata(path)?.len();
    let before_cells = before.saturating_sub(CELL as u64) / CELL as u64;
    let after_cells = after.saturating_sub(CELL as u64) / CELL as u64;
    Ok(CompactReport {
        before_cells,
        after_cells,
        dropped: before_cells.saturating_sub(after_cells),
        entries: store.len(),
        devices: store.devices().filter(|(_, d)| !d.is_empty()).count(),
        warnings: load_rep.warnings,
    })
}

/// What [`verify`] saw.
#[derive(Debug, Clone, Default)]
pub struct VerifyReport {
    pub cells: usize,
    pub data_cells: usize,
    pub footer_cells: usize,
    pub damaged: usize,
    pub torn_tail_bytes: usize,
    /// Live (post-supersede) entries and devices.
    pub entries: usize,
    pub devices: usize,
    /// A valid trailer closes the file.
    pub sealed: bool,
    /// Sealed, and every index offset points at a matching data cell.
    pub index_consistent: bool,
    pub warnings: Vec<String>,
}

impl VerifyReport {
    /// Nothing damaged, nothing torn, and any footer tells the truth.
    pub fn is_clean(&self) -> bool {
        self.damaged == 0
            && self.torn_tail_bytes == 0
            && (!self.sealed || self.index_consistent)
    }
}

/// Walk every checksum and, when sealed, audit the footer against the
/// data cells it claims to index. Errors only on an unreadable or
/// invalid header; damage is reported, not thrown.
pub fn verify(path: &Path) -> Result<VerifyReport> {
    let bytes =
        std::fs::read(path).with_context(|| format!("read tunedb {}", path.display()))?;
    record::check_header(&bytes)?;
    let body = &bytes[CELL..];
    let mut rep = VerifyReport {
        torn_tail_bytes: body.len() % CELL,
        ..Default::default()
    };
    if rep.torn_tail_bytes > 0 {
        rep.warnings.push(format!("torn tail: {} trailing byte(s)", rep.torn_tail_bytes));
    }
    let decoded: Vec<Result<Cell>> = body.chunks_exact(CELL).map(record::decode).collect();
    rep.cells = decoded.len();
    let mut store = TuneStore::new();
    for (i, d) in decoded.iter().enumerate() {
        match d {
            Ok(Cell::Data { fp, device, tuning }) => {
                store.insert(*fp, device, tuning.clone());
                rep.data_cells += 1;
            }
            Ok(_) => rep.footer_cells += 1,
            Err(e) => {
                rep.damaged += 1;
                rep.warnings.push(format!("block {}: {e:#}", i + 1));
            }
        }
    }
    rep.entries = store.len();
    rep.devices = store.devices().filter(|(_, d)| !d.is_empty()).count();
    if let Some(Ok(Cell::Trailer { index_start, index_cells, .. })) = decoded.last() {
        let last_block = decoded.len() as u64;
        if *index_start >= 1 && index_start + index_cells == last_block {
            rep.sealed = true;
            rep.index_consistent = true;
            for b in *index_start..last_block {
                match &decoded[(b - 1) as usize] {
                    Ok(Cell::Index { fp, blocks }) => {
                        for &db in blocks {
                            let target = (db >= 1 && db < *index_start)
                                .then(|| decoded.get((db - 1) as usize))
                                .flatten();
                            match target {
                                Some(Ok(Cell::Data { fp: dfp, .. })) if dfp == fp => {}
                                _ => {
                                    rep.index_consistent = false;
                                    rep.warnings.push(format!(
                                        "index block {b}: offset {db} does not point at a \
                                         record for fingerprint {fp:016x}"
                                    ));
                                }
                            }
                        }
                    }
                    _ => {
                        rep.index_consistent = false;
                        rep.warnings
                            .push(format!("footer block {b} is not a valid index cell"));
                    }
                }
            }
        }
    }
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::convgen::{Algorithm, TuneParams};
    use crate::workload::LayerClass;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ilpm_binstore_{name}_{}.tdb", std::process::id()))
    }

    fn entry(layer: LayerClass, alg: Algorithm, t: f64) -> StoredTuning {
        StoredTuning {
            layer,
            algorithm: alg,
            params: TuneParams::default(),
            time_ms: t,
            evaluated: 10,
            pruned: 1,
        }
    }

    #[test]
    fn append_load_round_trip_and_supersede() {
        let path = tmp("roundtrip");
        std::fs::remove_file(&path).ok();
        append(&path, 7, "mali", &entry(LayerClass::Conv2x, Algorithm::Ilpm, 2.0)).unwrap();
        append(&path, 7, "mali", &entry(LayerClass::Conv3x, Algorithm::Direct, 3.0)).unwrap();
        // same key appended again: the later record must win at load
        append(&path, 7, "mali", &entry(LayerClass::Conv2x, Algorithm::Ilpm, 1.0)).unwrap();
        let (store, rep) = load(&path).unwrap();
        assert_eq!(rep.data_cells, 3);
        assert_eq!(rep.skipped, 0);
        assert_eq!(store.len(), 2, "supersede-on-load merges duplicate keys");
        assert_eq!(store.get(7, LayerClass::Conv2x, Algorithm::Ilpm).unwrap().time_ms, 1.0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn seal_then_append_then_reseal_stays_loadable() {
        let path = tmp("reseal");
        std::fs::remove_file(&path).ok();
        append(&path, 1, "mali", &entry(LayerClass::Conv2x, Algorithm::Ilpm, 1.0)).unwrap();
        seal(&path).unwrap();
        let (_, rep) = load_device(&path, 1).unwrap();
        assert!(rep.indexed, "sealed file must serve an indexed read");
        // appending after the seal un-seals: the reader falls back to a
        // scan and still sees everything
        append(&path, 2, "vega8", &entry(LayerClass::Conv4x, Algorithm::Direct, 4.0)).unwrap();
        let (store, rep) = load_device(&path, 2).unwrap();
        assert!(!rep.indexed);
        assert_eq!(store.len(), 1);
        // resealing indexes both, with the stale footer left as dead
        // weight that a scan skips and verify counts as footer cells
        seal(&path).unwrap();
        let (store, rep) = load_device(&path, 2).unwrap();
        assert!(rep.indexed);
        assert_eq!(store.get(2, LayerClass::Conv4x, Algorithm::Direct).unwrap().time_ms, 4.0);
        assert_eq!(store.device(2).unwrap().device, "vega8");
        let v = verify(&path).unwrap();
        assert!(v.is_clean(), "{v:?}");
        assert!(v.sealed && v.index_consistent);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn torn_tail_is_skipped_on_load_and_repaired_on_append() {
        let path = tmp("torn");
        std::fs::remove_file(&path).ok();
        append(&path, 1, "mali", &entry(LayerClass::Conv2x, Algorithm::Ilpm, 1.0)).unwrap();
        // simulate a crash mid-append: half a record at the tail
        let mut bytes = std::fs::read(&path).unwrap();
        let half: Vec<u8> = bytes[CELL..CELL + CELL / 2].to_vec();
        bytes.extend_from_slice(&half);
        std::fs::write(&path, &bytes).unwrap();
        let (store, rep) = load(&path).unwrap();
        assert_eq!(store.len(), 1, "the complete record survives");
        assert_eq!(rep.torn_tail_bytes, CELL / 2);
        assert!(rep.warnings.iter().any(|w| w.contains("torn")), "{:?}", rep.warnings);
        // the next append truncates the torn tail and lands cleanly
        append(&path, 1, "mali", &entry(LayerClass::Conv5x, Algorithm::Direct, 5.0)).unwrap();
        let (store, rep) = load(&path).unwrap();
        assert_eq!(rep.torn_tail_bytes, 0);
        assert_eq!(rep.skipped, 0);
        assert_eq!(store.len(), 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wrong_magic_and_wrong_version_are_clean_errors() {
        let path = tmp("magic");
        std::fs::write(&path, b"{\"schema\":1}").unwrap();
        let err = format!("{:#}", load(&path).unwrap_err());
        assert!(err.contains("magic") || err.contains("truncated"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sealed_bytes_are_deterministic_and_compact_is_idempotent() {
        let path = tmp("compact");
        std::fs::remove_file(&path).ok();
        // build with superseded duplicates and a stale footer
        append(&path, 9, "mali", &entry(LayerClass::Conv2x, Algorithm::Ilpm, 2.0)).unwrap();
        seal(&path).unwrap();
        append(&path, 9, "mali", &entry(LayerClass::Conv2x, Algorithm::Ilpm, 1.0)).unwrap();
        append(&path, 3, "vega8", &entry(LayerClass::Conv3x, Algorithm::Direct, 3.0)).unwrap();
        seal(&path).unwrap();
        let (before, _) = load(&path).unwrap();
        let r1 = compact(&path).unwrap();
        assert!(r1.dropped > 0, "superseded + stale footer cells must go");
        let bytes1 = std::fs::read(&path).unwrap();
        let r2 = compact(&path).unwrap();
        assert_eq!(r2.dropped, 0);
        assert_eq!(bytes1, std::fs::read(&path).unwrap(), "compact must be idempotent");
        let (after, rep) = load(&path).unwrap();
        assert!(rep.indexed || rep.warnings.is_empty());
        assert_eq!(before.to_json().to_json_string(), after.to_json().to_json_string());
        // and deterministic: an equal in-memory store seals to the bytes
        assert_eq!(sealed_bytes(&after).unwrap(), bytes1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn is_binary_path_sniffs_and_falls_back_to_extension() {
        let bin = tmp("sniff");
        std::fs::remove_file(&bin).ok();
        append(&bin, 1, "mali", &entry(LayerClass::Conv2x, Algorithm::Ilpm, 1.0)).unwrap();
        assert!(is_binary_path(&bin));
        let json = std::env::temp_dir().join("ilpm_binstore_sniff.json");
        std::fs::write(&json, b"{}").unwrap();
        assert!(!is_binary_path(&json));
        assert!(is_binary_path(Path::new("/nonexistent/fresh.tdb")));
        assert!(!is_binary_path(Path::new("/nonexistent/fresh.json")));
        std::fs::remove_file(&bin).ok();
        std::fs::remove_file(&json).ok();
    }
}
