//! Fixed-layout binary cells for the tunedb segment file.
//!
//! Every block in a segment file — header, data record, index cell,
//! trailer — is exactly [`CELL`] bytes, so the file is uniformly framed
//! and a scan can never lose alignment: a damaged or unknown block
//! skips one cell and the stream recovers at the next boundary. All
//! integers are little-endian; the header carries an endianness probe
//! so a file written on a big-endian host (which would serialise the
//! probe reversed) is rejected with a clean error instead of silently
//! misread. Each cell's last 8 bytes are an FNV-1a 64 checksum over the
//! first 184, the same hash the device fingerprint uses
//! ([`crate::util::hash::fnv1a`]).
//!
//! The full layout is diagrammed in DESIGN.md ("tunedb binary segment
//! format").

use anyhow::{anyhow, bail, Result};

use crate::convgen::{Algorithm, TuneParams};
use crate::tunedb::StoredTuning;
use crate::util::hash::fnv1a;
use crate::workload::LayerClass;

/// Size of every block in the file, header included.
pub const CELL: usize = 192;
/// First 8 bytes of a binary store; sniffing this distinguishes the
/// segment format from the JSON store.
pub const MAGIC: [u8; 8] = *b"ILPMTDB\0";
/// Bump on any incompatible layout change; readers reject other
/// versions outright (same contract as the JSON `SCHEMA_VERSION`).
pub const BIN_SCHEMA_VERSION: u64 = 1;
/// Written little-endian at a fixed offset; reads back reversed on a
/// big-endian writer.
pub const ENDIAN_PROBE: u64 = 0x0102_0304_0506_0708;
/// Data-cell block indices one index cell can hold.
pub const INDEX_FANOUT: usize = 20;

const TAG_DATA: u64 = 1;
const TAG_INDEX: u64 = 2;
const TAG_TRAILER: u64 = 3;
const CHECKSUM_AT: usize = CELL - 8;

// Data-cell field offsets. The three name fields are zero-padded; a
// name that does not fit is rejected at append time, never truncated.
const DATA_FP: usize = 8;
const DATA_LAYER: usize = 16;
const DATA_LAYER_LEN: usize = 40;
const DATA_ALG: usize = 56;
const DATA_ALG_LEN: usize = 16;
const DATA_DEVICE: usize = 72;
const DATA_DEVICE_LEN: usize = 32;
const DATA_PARAMS: usize = 104; // 6 × u64 knobs
const DATA_FLAGS: usize = 152; // bit 0 cache_filters, bit 1 transpose_output
const DATA_TIME: usize = 160; // f64 bits
const DATA_EVALUATED: usize = 168;
const DATA_PRUNED: usize = 176;

const INDEX_FP: usize = 8;
const INDEX_COUNT: usize = 16;
const INDEX_OFFSETS: usize = 24;

const TRAILER_INDEX_START: usize = 8;
const TRAILER_INDEX_CELLS: usize = 16;
const TRAILER_DEVICES: usize = 24;
const TRAILER_COVERED: usize = 32;

/// A decoded, checksum-verified cell.
#[derive(Debug, Clone, PartialEq)]
pub enum Cell {
    Data { fp: u64, device: String, tuning: StoredTuning },
    /// Block indices (header = block 0) of data cells for one
    /// fingerprint; a device with more than [`INDEX_FANOUT`] records
    /// spans several index cells with the same `fp`.
    Index { fp: u64, blocks: Vec<u64> },
    /// Footer locator: the index spans blocks
    /// `[index_start, index_start + index_cells)` and covers the
    /// `covered` blocks before it; valid only as the file's last cell.
    Trailer { index_start: u64, index_cells: u64, devices: u64, covered: u64 },
}

fn put_u64(buf: &mut [u8; CELL], at: usize, v: u64) {
    buf[at..at + 8].copy_from_slice(&v.to_le_bytes());
}

fn get_u64(buf: &[u8], at: usize) -> u64 {
    u64::from_le_bytes(buf[at..at + 8].try_into().expect("8-byte field"))
}

fn put_name(buf: &mut [u8; CELL], at: usize, width: usize, s: &str, what: &str) -> Result<()> {
    if s.len() > width {
        bail!("{what} {s:?} is {} bytes, max {width} in the binary record", s.len());
    }
    buf[at..at + s.len()].copy_from_slice(s.as_bytes());
    Ok(())
}

fn get_name<'a>(buf: &'a [u8], at: usize, width: usize, what: &str) -> Result<&'a str> {
    let field = &buf[at..at + width];
    let end = field.iter().position(|&b| b == 0).unwrap_or(width);
    std::str::from_utf8(&field[..end]).map_err(|_| anyhow!("{what} field is not UTF-8"))
}

fn seal(mut buf: [u8; CELL]) -> [u8; CELL] {
    let sum = fnv1a(&buf[..CHECKSUM_AT]);
    put_u64(&mut buf, CHECKSUM_AT, sum);
    buf
}

/// Does the stored checksum match the cell's bytes?
pub fn checksum_ok(cell: &[u8]) -> bool {
    cell.len() == CELL && get_u64(cell, CHECKSUM_AT) == fnv1a(&cell[..CHECKSUM_AT])
}

/// The 192-byte file header: magic, schema version, endianness probe,
/// zero padding, checksum.
pub fn header_block() -> [u8; CELL] {
    let mut buf = [0u8; CELL];
    buf[..8].copy_from_slice(&MAGIC);
    put_u64(&mut buf, 8, BIN_SCHEMA_VERSION);
    put_u64(&mut buf, 16, ENDIAN_PROBE);
    seal(buf)
}

/// Validate a file header. Wrong magic, wrong version, a foreign-endian
/// writer, and a corrupted header are each a distinct clean error.
pub fn check_header(block: &[u8]) -> Result<()> {
    if block.len() < CELL {
        bail!("truncated header: {} bytes, need {CELL}", block.len());
    }
    let block = &block[..CELL];
    if block[..8] != MAGIC {
        bail!("not a binary tunedb store (bad magic); JSON stores load via TuneStore::load");
    }
    if !checksum_ok(block) {
        bail!("header checksum mismatch — corrupted store header");
    }
    let version = get_u64(block, 8);
    if version != BIN_SCHEMA_VERSION {
        bail!(
            "unsupported binary tunedb schema v{version} (this build reads \
             v{BIN_SCHEMA_VERSION}); re-migrate with `ilpm tunedb migrate`"
        );
    }
    let probe = get_u64(block, 16);
    if probe != ENDIAN_PROBE {
        bail!("endianness probe mismatch ({probe:#018x}) — store written on a foreign-endian host");
    }
    Ok(())
}

/// Encode one tuning record. Rejects non-finite `time_ms` (the binary
/// append-time guard, mirroring the JSON parse-time guard) and names
/// that do not fit their fixed field.
pub fn encode_data(fp: u64, device: &str, t: &StoredTuning) -> Result<[u8; CELL]> {
    if !t.time_ms.is_finite() {
        bail!(
            "non-finite time_ms {} for ({}, {}) — rejected at append time",
            t.time_ms,
            t.layer.name(),
            t.algorithm.name()
        );
    }
    let mut buf = [0u8; CELL];
    put_u64(&mut buf, 0, TAG_DATA);
    put_u64(&mut buf, DATA_FP, fp);
    put_name(&mut buf, DATA_LAYER, DATA_LAYER_LEN, &t.layer.name(), "layer name")?;
    put_name(&mut buf, DATA_ALG, DATA_ALG_LEN, t.algorithm.name(), "algorithm name")?;
    put_name(&mut buf, DATA_DEVICE, DATA_DEVICE_LEN, device, "device name")?;
    let p = &t.params;
    for (i, v) in [p.wg_size, p.tile_m, p.tile_n, p.tile_k, p.tile_px, p.k_per_thread]
        .into_iter()
        .enumerate()
    {
        put_u64(&mut buf, DATA_PARAMS + i * 8, v);
    }
    let flags = (p.cache_filters as u64) | ((p.transpose_output as u64) << 1);
    put_u64(&mut buf, DATA_FLAGS, flags);
    put_u64(&mut buf, DATA_TIME, t.time_ms.to_bits());
    put_u64(&mut buf, DATA_EVALUATED, t.evaluated as u64);
    put_u64(&mut buf, DATA_PRUNED, t.pruned as u64);
    Ok(seal(buf))
}

/// Encode one index cell: up to [`INDEX_FANOUT`] data-cell block
/// indices for one fingerprint.
pub fn encode_index(fp: u64, blocks: &[u64]) -> [u8; CELL] {
    assert!(
        !blocks.is_empty() && blocks.len() <= INDEX_FANOUT,
        "index cell holds 1..={INDEX_FANOUT} offsets, got {}",
        blocks.len()
    );
    let mut buf = [0u8; CELL];
    put_u64(&mut buf, 0, TAG_INDEX);
    put_u64(&mut buf, INDEX_FP, fp);
    put_u64(&mut buf, INDEX_COUNT, blocks.len() as u64);
    for (i, &b) in blocks.iter().enumerate() {
        put_u64(&mut buf, INDEX_OFFSETS + i * 8, b);
    }
    seal(buf)
}

/// Encode the trailer cell closing a footer.
pub fn encode_trailer(index_start: u64, index_cells: u64, devices: u64, covered: u64) -> [u8; CELL] {
    let mut buf = [0u8; CELL];
    put_u64(&mut buf, 0, TAG_TRAILER);
    put_u64(&mut buf, TRAILER_INDEX_START, index_start);
    put_u64(&mut buf, TRAILER_INDEX_CELLS, index_cells);
    put_u64(&mut buf, TRAILER_DEVICES, devices);
    put_u64(&mut buf, TRAILER_COVERED, covered);
    seal(buf)
}

/// Decode and fully validate one cell. Any failure — bad checksum,
/// unknown tag, unknown layer/algorithm name, non-finite time — is an
/// error the caller treats as "damaged cell: skip and warn"; decode
/// never panics on arbitrary bytes.
pub fn decode(cell: &[u8]) -> Result<Cell> {
    if cell.len() != CELL {
        bail!("cell is {} bytes, expected {CELL}", cell.len());
    }
    if !checksum_ok(cell) {
        bail!("checksum mismatch");
    }
    match get_u64(cell, 0) {
        TAG_DATA => {
            let layer_name = get_name(cell, DATA_LAYER, DATA_LAYER_LEN, "layer")?;
            let layer = LayerClass::from_name(layer_name)
                .ok_or_else(|| anyhow!("unknown layer {layer_name:?}"))?;
            let alg_name = get_name(cell, DATA_ALG, DATA_ALG_LEN, "algorithm")?;
            let algorithm = Algorithm::from_name(alg_name)
                .ok_or_else(|| anyhow!("unknown algorithm {alg_name:?}"))?;
            let device = get_name(cell, DATA_DEVICE, DATA_DEVICE_LEN, "device")?.to_string();
            let flags = get_u64(cell, DATA_FLAGS);
            if flags & !0b11 != 0 {
                bail!("unknown flag bits {flags:#x}");
            }
            let time_ms = f64::from_bits(get_u64(cell, DATA_TIME));
            if !time_ms.is_finite() {
                bail!("non-finite time_ms {time_ms}");
            }
            let params = TuneParams {
                wg_size: get_u64(cell, DATA_PARAMS),
                tile_m: get_u64(cell, DATA_PARAMS + 8),
                tile_n: get_u64(cell, DATA_PARAMS + 16),
                tile_k: get_u64(cell, DATA_PARAMS + 24),
                tile_px: get_u64(cell, DATA_PARAMS + 32),
                k_per_thread: get_u64(cell, DATA_PARAMS + 40),
                cache_filters: flags & 1 != 0,
                transpose_output: flags & 2 != 0,
            };
            Ok(Cell::Data {
                fp: get_u64(cell, DATA_FP),
                device,
                tuning: StoredTuning {
                    layer,
                    algorithm,
                    params,
                    time_ms,
                    evaluated: get_u64(cell, DATA_EVALUATED) as usize,
                    pruned: get_u64(cell, DATA_PRUNED) as usize,
                },
            })
        }
        TAG_INDEX => {
            let count = get_u64(cell, INDEX_COUNT);
            if count == 0 || count > INDEX_FANOUT as u64 {
                bail!("index cell claims {count} offsets, max {INDEX_FANOUT}");
            }
            let blocks = (0..count as usize)
                .map(|i| get_u64(cell, INDEX_OFFSETS + i * 8))
                .collect();
            Ok(Cell::Index { fp: get_u64(cell, INDEX_FP), blocks })
        }
        TAG_TRAILER => Ok(Cell::Trailer {
            index_start: get_u64(cell, TRAILER_INDEX_START),
            index_cells: get_u64(cell, TRAILER_INDEX_CELLS),
            devices: get_u64(cell, TRAILER_DEVICES),
            covered: get_u64(cell, TRAILER_COVERED),
        }),
        other => bail!("unknown cell tag {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> StoredTuning {
        StoredTuning {
            layer: LayerClass::Pw { in_channels: 512, out_channels: 512, hw: 14 },
            algorithm: Algorithm::Dwconv,
            params: TuneParams {
                wg_size: 128,
                tile_m: 8,
                tile_n: 32,
                tile_k: 16,
                tile_px: 4,
                k_per_thread: 2,
                cache_filters: true,
                transpose_output: false,
            },
            time_ms: 1.5,
            evaluated: 77,
            pruned: 3,
        }
    }

    #[test]
    fn data_cell_round_trips_every_field() {
        let t = sample();
        let cell = encode_data(0xdead_beef, "mali-g76", &t).unwrap();
        match decode(&cell).unwrap() {
            Cell::Data { fp, device, tuning } => {
                assert_eq!(fp, 0xdead_beef);
                assert_eq!(device, "mali-g76");
                assert_eq!(tuning, t);
            }
            other => panic!("decoded {other:?}"),
        }
    }

    #[test]
    fn header_validates_and_rejects_tampering() {
        let h = header_block();
        check_header(&h).unwrap();
        // wrong magic
        let mut bad = h;
        bad[0] ^= 0xff;
        assert!(format!("{:#}", check_header(&bad).unwrap_err()).contains("magic"));
        // future version (checksum re-sealed so the version check fires)
        let mut future = [0u8; CELL];
        future[..8].copy_from_slice(&MAGIC);
        put_u64(&mut future, 8, BIN_SCHEMA_VERSION + 1);
        put_u64(&mut future, 16, ENDIAN_PROBE);
        let future = seal(future);
        assert!(format!("{:#}", check_header(&future).unwrap_err()).contains("schema"));
        // flipped endianness probe
        let mut foreign = [0u8; CELL];
        foreign[..8].copy_from_slice(&MAGIC);
        put_u64(&mut foreign, 8, BIN_SCHEMA_VERSION);
        put_u64(&mut foreign, 16, ENDIAN_PROBE.swap_bytes());
        let foreign = seal(foreign);
        assert!(format!("{:#}", check_header(&foreign).unwrap_err()).contains("endian"));
        // corrupted padding breaks the checksum
        let mut torn = h;
        torn[100] = 9;
        assert!(format!("{:#}", check_header(&torn).unwrap_err()).contains("checksum"));
    }

    #[test]
    fn every_single_bit_flip_is_caught_by_the_checksum() {
        let cell = encode_data(7, "vega8", &sample()).unwrap();
        for byte in 0..CELL {
            for bit in 0..8 {
                let mut flipped = cell;
                flipped[byte] ^= 1 << bit;
                assert!(
                    decode(&flipped).is_err(),
                    "flip of byte {byte} bit {bit} decoded successfully"
                );
            }
        }
    }

    #[test]
    fn non_finite_time_rejected_on_encode_and_decode() {
        let mut t = sample();
        t.time_ms = f64::NAN;
        assert!(encode_data(1, "mali", &t).is_err());
        t.time_ms = f64::INFINITY;
        assert!(encode_data(1, "mali", &t).is_err());
        // a hand-crafted cell with NaN bits and a *valid* checksum must
        // still be rejected: accepted loads are finite by construction
        t.time_ms = 1.0;
        let mut cell = encode_data(1, "mali", &t).unwrap();
        put_u64(&mut cell, DATA_TIME, f64::NAN.to_bits());
        let cell = seal(cell);
        assert!(format!("{:#}", decode(&cell).unwrap_err()).contains("non-finite"));
    }

    #[test]
    fn oversized_device_name_is_a_clean_append_error() {
        let long = "x".repeat(DATA_DEVICE_LEN + 1);
        let err = encode_data(1, &long, &sample()).unwrap_err();
        assert!(format!("{err:#}").contains("device name"));
    }

    #[test]
    fn index_and_trailer_round_trip() {
        let blocks: Vec<u64> = (1..=INDEX_FANOUT as u64).collect();
        match decode(&encode_index(42, &blocks)).unwrap() {
            Cell::Index { fp, blocks: b } => {
                assert_eq!(fp, 42);
                assert_eq!(b, blocks);
            }
            other => panic!("decoded {other:?}"),
        }
        match decode(&encode_trailer(10, 2, 3, 9)).unwrap() {
            Cell::Trailer { index_start, index_cells, devices, covered } => {
                assert_eq!((index_start, index_cells, devices, covered), (10, 2, 3, 9));
            }
            other => panic!("decoded {other:?}"),
        }
    }

    #[test]
    fn worst_case_layer_name_fits_the_fixed_field() {
        // the widest printable key: pw{u32}-{u32}@{u32}
        let layer = LayerClass::Pw {
            in_channels: u32::MAX,
            out_channels: u32::MAX,
            hw: u32::MAX,
        };
        assert!(layer.name().len() <= DATA_LAYER_LEN, "{}", layer.name());
        for alg in Algorithm::ALL {
            assert!(alg.name().len() <= DATA_ALG_LEN);
        }
    }
}
