//! The on-disk store: versioned JSON, atomic writes, fingerprint keys.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::autotune::{SearchStats, TunedEntry, TuningDatabase};
use crate::convgen::{Algorithm, TuneParams};
use crate::simulator::DeviceConfig;
use crate::util::json::Json;
use crate::workload::LayerClass;

/// Bump on any incompatible change to the file layout. Readers reject
/// other versions outright: a tuning table silently misread is worse
/// than one re-tuned from scratch.
pub const SCHEMA_VERSION: u64 = 1;

/// One persisted tuning result for a `(layer, algorithm)` on a device.
#[derive(Debug, Clone, PartialEq)]
pub struct StoredTuning {
    pub layer: LayerClass,
    pub algorithm: Algorithm,
    pub params: TuneParams,
    /// Simulated time at the chosen configuration (ms).
    pub time_ms: f64,
    /// Candidates the original search evaluated (provenance; a
    /// warm-start hit re-evaluates none of them).
    pub evaluated: usize,
    /// Candidates the original search pruned for not fitting the device.
    pub pruned: usize,
}

impl StoredTuning {
    pub fn from_entry(e: &TunedEntry) -> StoredTuning {
        StoredTuning {
            layer: e.layer,
            algorithm: e.algorithm,
            params: e.params,
            time_ms: e.time_ms,
            evaluated: e.stats.evaluated,
            pruned: e.stats.pruned,
        }
    }

    /// Rehydrate into an autotune entry. Simulation reports are not
    /// persisted (they are recomputable), so `reports` is empty.
    pub fn to_entry(&self, device: &str) -> TunedEntry {
        TunedEntry {
            device: device.to_string(),
            layer: self.layer,
            algorithm: self.algorithm,
            params: self.params,
            time_ms: self.time_ms,
            reports: Vec::new(),
            stats: SearchStats { evaluated: self.evaluated, pruned: self.pruned },
        }
    }
}

/// All persisted tunings for one device fingerprint.
#[derive(Debug, Clone, Default)]
pub struct DeviceTunings {
    /// Human-readable device name (display only; the fingerprint is the
    /// key — two specs sharing a name do not share entries).
    pub device: String,
    entries: HashMap<(LayerClass, Algorithm), StoredTuning>,
}

impl DeviceTunings {
    pub fn get(&self, layer: LayerClass, alg: Algorithm) -> Option<&StoredTuning> {
        self.entries.get(&(layer, alg))
    }

    pub fn entries(&self) -> impl Iterator<Item = &StoredTuning> {
        self.entries.values()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Fastest stored algorithm for a layer, if any. Ties break by
    /// algorithm name (the routing tie-break), and the ordering is
    /// total: a NaN smuggled in through `insert` yields a deterministic
    /// winner instead of a panic mid-comparison.
    pub fn best_algorithm(&self, layer: LayerClass) -> Option<&StoredTuning> {
        self.entries.values().filter(|t| t.layer == layer).min_by(|a, b| {
            a.time_ms
                .total_cmp(&b.time_ms)
                .then_with(|| a.algorithm.name().cmp(b.algorithm.name()))
        })
    }
}

/// The persistent tuning store: a fleet of devices in one file.
///
/// R3 (ordered-output) audit: both `HashMap` levels (devices here,
/// entries in [`DeviceTunings`]) are lookup-only; [`Self::to_json`]
/// sorts devices by fingerprint and entries by `(layer, algorithm)`
/// before emission, so identical stores serialise byte-identically.
#[derive(Debug, Clone, Default)]
pub struct TuneStore {
    devices: HashMap<u64, DeviceTunings>,
}

impl TuneStore {
    pub fn new() -> TuneStore {
        TuneStore::default()
    }

    /// Look up one `(device fingerprint, layer, algorithm)` key.
    pub fn get(&self, fp: u64, layer: LayerClass, alg: Algorithm) -> Option<&StoredTuning> {
        self.devices.get(&fp)?.get(layer, alg)
    }

    pub fn contains(&self, fp: u64, layer: LayerClass, alg: Algorithm) -> bool {
        self.get(fp, layer, alg).is_some()
    }

    /// Insert or overwrite one entry under a device fingerprint.
    pub fn insert(&mut self, fp: u64, device: &str, t: StoredTuning) {
        let d = self.devices.entry(fp).or_default();
        if d.device.is_empty() {
            d.device = device.to_string();
        }
        d.entries.insert((t.layer, t.algorithm), t);
    }

    /// Merge one freshly-tuned entry for `dev` into the store.
    pub fn merge_entry(&mut self, dev: &DeviceConfig, e: &TunedEntry) {
        self.insert(dev.fingerprint(), dev.name, StoredTuning::from_entry(e));
    }

    /// Merge every entry of an in-memory database. `devices` supplies
    /// the fingerprints; entries for devices not listed are skipped
    /// (a name alone cannot be fingerprinted).
    pub fn merge_database(&mut self, db: &TuningDatabase, devices: &[DeviceConfig]) {
        for dev in devices {
            for e in db.entries().filter(|e| e.device == dev.name) {
                self.merge_entry(dev, e);
            }
        }
    }

    /// Rehydrate the stored entries for one device into an in-memory
    /// database (empty when the fingerprint has no entries).
    pub fn to_database(&self, dev: &DeviceConfig) -> TuningDatabase {
        let mut db = TuningDatabase::default();
        if let Some(d) = self.devices.get(&dev.fingerprint()) {
            for t in d.entries() {
                db.insert(t.to_entry(dev.name));
            }
        }
        db
    }

    /// The stored tunings for one device fingerprint.
    pub fn device(&self, fp: u64) -> Option<&DeviceTunings> {
        self.devices.get(&fp)
    }

    /// All `(fingerprint, tunings)` pairs, unordered.
    pub fn devices(&self) -> impl Iterator<Item = (u64, &DeviceTunings)> {
        self.devices.iter().map(|(fp, d)| (*fp, d))
    }

    /// Drop every entry for one device fingerprint.
    pub fn remove_device(&mut self, fp: u64) -> bool {
        self.devices.remove(&fp).is_some()
    }

    pub fn device_count(&self) -> usize {
        self.devices.len()
    }

    /// Total entries across all devices.
    pub fn len(&self) -> usize {
        self.devices.values().map(DeviceTunings::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.values().all(DeviceTunings::is_empty)
    }

    // ---- persistence -------------------------------------------------

    /// Serialise deterministically: devices ordered by fingerprint,
    /// entries by `(layer, algorithm)` name, so identical stores yield
    /// byte-identical files (diff-able, content-addressable).
    pub fn to_json(&self) -> Json {
        use std::collections::BTreeMap;
        let mut devices: Vec<(&u64, &DeviceTunings)> = self.devices.iter().collect();
        devices.sort_by_key(|(fp, _)| **fp);
        let dev_arr: Vec<Json> = devices
            .into_iter()
            .map(|(fp, d)| {
                let mut entries: Vec<&StoredTuning> = d.entries.values().collect();
                entries.sort_by_key(|t| (t.layer.name(), t.algorithm.name()));
                let ent_arr: Vec<Json> = entries
                    .into_iter()
                    .map(|t| {
                        let mut m = BTreeMap::new();
                        m.insert("layer".into(), Json::Str(t.layer.name()));
                        m.insert("algorithm".into(), Json::Str(t.algorithm.name().into()));
                        m.insert("time_ms".into(), Json::Num(t.time_ms));
                        m.insert("evaluated".into(), Json::Num(t.evaluated as f64));
                        m.insert("pruned".into(), Json::Num(t.pruned as f64));
                        m.insert("params".into(), t.params.to_json());
                        Json::Obj(m)
                    })
                    .collect();
                let mut m = BTreeMap::new();
                m.insert("fingerprint".into(), Json::Str(format!("{fp:016x}")));
                m.insert("device".into(), Json::Str(d.device.clone()));
                m.insert("entries".into(), Json::Arr(ent_arr));
                Json::Obj(m)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("schema".into(), Json::Num(SCHEMA_VERSION as f64));
        root.insert("tool".into(), Json::Str("ilpm-tunedb".into()));
        root.insert("devices".into(), Json::Arr(dev_arr));
        Json::Obj(root)
    }

    /// Parse a store serialised by [`Self::to_json`]. Rejects any other
    /// schema version with an actionable error.
    pub fn parse(text: &str) -> Result<TuneStore> {
        let root = Json::parse(text).context("tunedb is not valid JSON")?;
        if root.as_arr().is_some() {
            // the pre-tunedb `TuningDatabase::save` format was a flat
            // array; give those users a way out instead of a dead end
            bail!(
                "this is a legacy flat tuning table, not a tunedb store; \
                 load it with `TuningDatabase::load` or regenerate it with \
                 `ilpm tune --out` against a fresh path"
            );
        }
        let schema = root
            .get("schema")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("missing schema version"))?;
        if schema != SCHEMA_VERSION {
            bail!(
                "unsupported tunedb schema v{schema} (this build reads v{SCHEMA_VERSION}); \
                 re-tune with `ilpm tune --out`"
            );
        }
        let mut store = TuneStore::new();
        let devices = root
            .get("devices")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing devices array"))?;
        for (i, d) in devices.iter().enumerate() {
            let fp_hex = d
                .get("fingerprint")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("device {i}: missing fingerprint"))?;
            let fp = u64::from_str_radix(fp_hex, 16)
                .map_err(|_| anyhow!("device {i}: bad fingerprint {fp_hex:?}"))?;
            let name = d
                .get("device")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("device {i}: missing name"))?;
            let entries = d
                .get("entries")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("device {i}: missing entries"))?;
            for (j, e) in entries.iter().enumerate() {
                let t = parse_entry(e).with_context(|| format!("device {name}, entry {j}"))?;
                store.insert(fp, name, t);
            }
            // a tuned-but-empty device is still worth remembering
            store.devices.entry(fp).or_default().device = name.to_string();
        }
        Ok(store)
    }

    /// Load a store from disk. A missing file is an error; use
    /// [`Self::load_or_empty`] where absence means "cold start".
    pub fn load(path: &Path) -> Result<TuneStore> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read tunedb {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parse tunedb {}", path.display()))
    }

    /// Load a store, treating a missing file as an empty store. A file
    /// that exists but fails to parse is still an error — corrupt state
    /// should never be silently discarded.
    pub fn load_or_empty(path: &Path) -> Result<TuneStore> {
        if path.exists() {
            Self::load(path)
        } else {
            Ok(TuneStore::new())
        }
    }

    /// Persist atomically: serialise to a sibling temp file, then
    /// rename over the target. Readers never observe a half-written
    /// store, and a crash mid-save leaves the previous version intact.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("create dir {}", dir.display()))?;
        }
        let stem = path
            .file_name()
            .and_then(|s| s.to_str())
            .unwrap_or("tunedb.json");
        let tmp = path.with_file_name(format!(".{stem}.tmp.{}", std::process::id()));
        let text = self.to_json().to_json_string();
        std::fs::write(&tmp, text.as_bytes())
            .with_context(|| format!("write {}", tmp.display()))?;
        std::fs::rename(&tmp, path).with_context(|| {
            let _ = std::fs::remove_file(&tmp);
            format!("rename {} -> {}", tmp.display(), path.display())
        })?;
        Ok(())
    }
}

fn parse_entry(e: &Json) -> Result<StoredTuning> {
    let get_str =
        |k: &str| e.get(k).and_then(Json::as_str).ok_or_else(|| anyhow!("missing {k}"));
    let layer_name = get_str("layer")?;
    let layer = LayerClass::from_name(layer_name)
        .ok_or_else(|| anyhow!("unknown layer {layer_name:?}"))?;
    let alg_name = get_str("algorithm")?;
    let algorithm = Algorithm::from_name(alg_name)
        .ok_or_else(|| anyhow!("unknown algorithm {alg_name:?}"))?;
    let params = TuneParams::from_json(
        e.get("params").ok_or_else(|| anyhow!("missing params"))?,
    )?;
    let time_ms = e
        .get("time_ms")
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("missing time_ms"))?;
    // the JSON parser happily yields inf from overflow literals like
    // 1e999; a non-finite "best time" poisons every later comparison,
    // so refuse it here, at the trust boundary
    if !time_ms.is_finite() {
        bail!("non-finite time_ms ({time_ms})");
    }
    Ok(StoredTuning {
        layer,
        algorithm,
        params,
        time_ms,
        evaluated: e.get("evaluated").and_then(Json::as_usize).unwrap_or(0),
        pruned: e.get("pruned").and_then(Json::as_usize).unwrap_or(0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(layer: LayerClass, alg: Algorithm, t: f64) -> StoredTuning {
        StoredTuning {
            layer,
            algorithm: alg,
            params: TuneParams::default(),
            time_ms: t,
            evaluated: 42,
            pruned: 3,
        }
    }

    #[test]
    fn insert_get_and_best() {
        let dev = DeviceConfig::mali_g76_mp10();
        let fp = dev.fingerprint();
        let mut s = TuneStore::new();
        s.insert(fp, dev.name, sample(LayerClass::Conv4x, Algorithm::Ilpm, 1.0));
        s.insert(fp, dev.name, sample(LayerClass::Conv4x, Algorithm::Direct, 2.0));
        assert_eq!(s.len(), 2);
        assert!(s.contains(fp, LayerClass::Conv4x, Algorithm::Ilpm));
        assert!(!s.contains(fp, LayerClass::Conv2x, Algorithm::Ilpm));
        let best = s.device(fp).unwrap().best_algorithm(LayerClass::Conv4x).unwrap();
        assert_eq!(best.algorithm, Algorithm::Ilpm);
    }

    #[test]
    fn serialisation_is_deterministic() {
        let mut s = TuneStore::new();
        for dev in DeviceConfig::paper_devices() {
            s.insert(dev.fingerprint(), dev.name, sample(LayerClass::Conv2x, Algorithm::Ilpm, 0.5));
            s.insert(dev.fingerprint(), dev.name, sample(LayerClass::Conv5x, Algorithm::Direct, 0.7));
        }
        let a = s.to_json().to_json_string();
        let b = TuneStore::parse(&a).unwrap().to_json().to_json_string();
        assert_eq!(a, b, "parse∘serialise must be the identity on the wire format");
    }

    #[test]
    fn schema_version_rejected() {
        let mut j = TuneStore::new().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("schema".into(), Json::Num((SCHEMA_VERSION + 1) as f64));
        }
        let err = TuneStore::parse(&j.to_json_string()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("schema"), "{msg}");
    }

    #[test]
    fn legacy_flat_table_is_diagnosed() {
        // the old `TuningDatabase::save` wrote a flat JSON array; the
        // store must name the problem instead of "missing schema"
        let err = TuneStore::parse("[]").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("legacy"), "{msg}");
    }

    #[test]
    fn atomic_save_leaves_no_temp_files() {
        let dir = std::env::temp_dir().join(format!("ilpm_tunedb_{}", std::process::id()));
        let path = dir.join("store.json");
        let dev = DeviceConfig::vega8();
        let mut s = TuneStore::new();
        s.insert(dev.fingerprint(), dev.name, sample(LayerClass::Conv3x, Algorithm::Im2col, 3.0));
        s.save(&path).unwrap();
        // overwrite must also succeed (rename over existing file)
        s.insert(dev.fingerprint(), dev.name, sample(LayerClass::Conv3x, Algorithm::Ilpm, 1.0));
        s.save(&path).unwrap();
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["store.json".to_string()], "stray files: {names:?}");
        let back = TuneStore::load(&path).unwrap();
        assert_eq!(back.len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn non_finite_time_ms_is_rejected_at_parse() {
        // regression: the JSON parser turns the overflow literal 1e999
        // into inf, and parse() used to accept it — after which
        // best_algorithm's partial_cmp().unwrap() could panic
        let mut s = TuneStore::new();
        let dev = DeviceConfig::mali_g76_mp10();
        s.insert(dev.fingerprint(), dev.name, sample(LayerClass::Conv2x, Algorithm::Ilpm, 1.0));
        let good = s.to_json().to_json_string();
        for bad_literal in ["1e999", "-1e999"] {
            let text = good.replace("\"time_ms\":1", &format!("\"time_ms\":{bad_literal}"));
            assert_ne!(text, good, "replacement must hit the time_ms field");
            let err = format!("{:#}", TuneStore::parse(&text).unwrap_err());
            assert!(err.contains("non-finite"), "{bad_literal}: {err}");
        }
    }

    #[test]
    fn best_algorithm_survives_nan_and_breaks_ties_by_name() {
        let dev = DeviceConfig::mali_g76_mp10();
        let fp = dev.fingerprint();
        // regression: a NaN inserted in-memory used to panic the
        // min_by(partial_cmp().unwrap()) comparison
        let mut s = TuneStore::new();
        s.insert(fp, dev.name, sample(LayerClass::Conv4x, Algorithm::Ilpm, f64::NAN));
        s.insert(fp, dev.name, sample(LayerClass::Conv4x, Algorithm::Direct, 2.0));
        s.insert(fp, dev.name, sample(LayerClass::Conv4x, Algorithm::Im2col, f64::NAN));
        let best = s.device(fp).unwrap().best_algorithm(LayerClass::Conv4x).unwrap();
        assert_eq!(best.algorithm, Algorithm::Direct, "finite entry beats NaN entries");
        // exact tie: the alphabetically-first algorithm name wins, the
        // same rule the router uses, so store and router agree
        let mut s = TuneStore::new();
        s.insert(fp, dev.name, sample(LayerClass::Conv3x, Algorithm::Winograd, 1.5));
        s.insert(fp, dev.name, sample(LayerClass::Conv3x, Algorithm::Direct, 1.5));
        s.insert(fp, dev.name, sample(LayerClass::Conv3x, Algorithm::Ilpm, 1.5));
        let best = s.device(fp).unwrap().best_algorithm(LayerClass::Conv3x).unwrap();
        assert_eq!(best.algorithm, Algorithm::Direct);
    }

    #[test]
    fn load_or_empty_missing_vs_corrupt() {
        let missing = std::env::temp_dir().join("ilpm_tunedb_definitely_missing.json");
        assert!(TuneStore::load_or_empty(&missing).unwrap().is_empty());
        let corrupt = std::env::temp_dir().join(format!("ilpm_tunedb_corrupt_{}.json", std::process::id()));
        std::fs::write(&corrupt, b"{not json").unwrap();
        assert!(TuneStore::load_or_empty(&corrupt).is_err());
        std::fs::remove_file(&corrupt).ok();
    }
}
