//! tunedb — the persistent tuning store.
//!
//! The paper's engineering argument (§2.3) is that an inference network
//! is frozen: per-layer algorithm and parameter choices can be tuned
//! *once per device* and reused forever. In-memory
//! [`crate::autotune::TuningDatabase`] results died with the process;
//! this module makes them durable:
//!
//! * [`TuneStore`] — a versioned on-disk store (JSON via
//!   [`crate::util::json`], no new deps) written atomically
//!   (write-then-rename), holding entries for a whole device fleet in
//!   one file.
//! * Entries are keyed by a **device fingerprint** —
//!   [`crate::simulator::DeviceConfig::fingerprint`], a stable FNV-1a
//!   hash of *every* field of the device spec — plus
//!   `(LayerClass, Algorithm)`. The layer key carries the full class
//!   geometry (a depthwise `dw64s1@56` and the dense `conv2.x` with
//!   identical C/K/H/W are distinct keys: their `groups` differ, so
//!   their lowerings and winners do too). Editing any device parameter
//!   changes the fingerprint, so stale results for that device
//!   silently miss and get re-tuned, while other devices' entries stay
//!   valid.
//! * [`crate::autotune::tune_all_warm`] warm-starts the exhaustive
//!   search from a store: keys already present are loaded instead of
//!   swept (a second run evaluates zero candidates), fresh results are
//!   merged back.
//! * [`crate::coordinator::RoutingTable::from_store`] builds the
//!   serve-time per-layer routing straight from disk — zero simulator
//!   evaluations on the serving path.
//!
//! File format and invalidation rules are documented in DESIGN.md.
//!
//! Two wire formats share one data model:
//!
//! * **JSON (schema v1)** — [`TuneStore`]'s own format: human-diffable,
//!   whole-store read-modify-write. The interop/export format.
//! * **Binary (`.tdb`, [`binstore`])** — an append-only segment file of
//!   fixed-layout checksummed records with a per-fingerprint index
//!   footer: a serve replica loads *its* routes by seeking, not by
//!   parsing every device ever tuned, and concurrent tuners merge back
//!   by appending instead of the JSON store's lossy rewrite. The fleet
//!   format.
//!
//! [`load_any`] / [`load_any_or_empty`] sniff which format a path holds
//! so every CLI entry point accepts either; `ilpm tunedb
//! migrate|export|compact|verify` manages the binary lifecycle.

pub mod binstore;
mod record;
mod store;

pub use store::{DeviceTunings, StoredTuning, TuneStore, SCHEMA_VERSION};

use std::path::Path;

use anyhow::Result;

/// Load a store from either wire format, sniffing the file's magic.
/// Binary repair warnings (torn tail, damaged cells) are logged.
pub fn load_any(path: &Path) -> Result<TuneStore> {
    if binstore::is_binstore(path) {
        let (store, rep) = binstore::load(path)?;
        for w in &rep.warnings {
            crate::log_warn!("tunedb {}: {w}", path.display());
        }
        Ok(store)
    } else {
        TuneStore::load(path)
    }
}

/// [`load_any`], treating a missing file as an empty store (cold
/// start). A file that exists but fails to load is still an error.
pub fn load_any_or_empty(path: &Path) -> Result<TuneStore> {
    if path.exists() {
        load_any(path)
    } else {
        Ok(TuneStore::new())
    }
}
