//! tunedb — the persistent tuning store.
//!
//! The paper's engineering argument (§2.3) is that an inference network
//! is frozen: per-layer algorithm and parameter choices can be tuned
//! *once per device* and reused forever. In-memory
//! [`crate::autotune::TuningDatabase`] results died with the process;
//! this module makes them durable:
//!
//! * [`TuneStore`] — a versioned on-disk store (JSON via
//!   [`crate::util::json`], no new deps) written atomically
//!   (write-then-rename), holding entries for a whole device fleet in
//!   one file.
//! * Entries are keyed by a **device fingerprint** —
//!   [`crate::simulator::DeviceConfig::fingerprint`], a stable FNV-1a
//!   hash of *every* field of the device spec — plus
//!   `(LayerClass, Algorithm)`. The layer key carries the full class
//!   geometry (a depthwise `dw64s1@56` and the dense `conv2.x` with
//!   identical C/K/H/W are distinct keys: their `groups` differ, so
//!   their lowerings and winners do too). Editing any device parameter
//!   changes the fingerprint, so stale results for that device
//!   silently miss and get re-tuned, while other devices' entries stay
//!   valid.
//! * [`crate::autotune::tune_all_warm`] warm-starts the exhaustive
//!   search from a store: keys already present are loaded instead of
//!   swept (a second run evaluates zero candidates), fresh results are
//!   merged back.
//! * [`crate::coordinator::RoutingTable::from_store`] builds the
//!   serve-time per-layer routing straight from disk — zero simulator
//!   evaluations on the serving path.
//!
//! File format and invalidation rules are documented in DESIGN.md.

mod store;

pub use store::{DeviceTunings, StoredTuning, TuneStore, SCHEMA_VERSION};
