//! FNV-1a 64-bit — a tiny, stable, dependency-free hash.
//!
//! The tunedb keys persistent entries by a *fingerprint* of the full
//! [`crate::simulator::DeviceConfig`]; that hash must be identical
//! across processes, platforms and compiler versions, which rules out
//! `std::hash` (SipHash with random keys and no stability guarantee).
//! FNV-1a over a canonical byte encoding is deterministic forever.

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Streaming FNV-1a 64-bit hasher.
#[derive(Debug, Clone)]
pub struct Fnv1a {
    state: u64,
}

impl Default for Fnv1a {
    fn default() -> Self {
        Fnv1a { state: FNV_OFFSET }
    }
}

impl Fnv1a {
    pub fn new() -> Fnv1a {
        Fnv1a::default()
    }

    /// Absorb bytes. Length-prefix variable-length fields yourself when
    /// concatenation ambiguity matters (the fingerprint does).
    pub fn update(&mut self, bytes: &[u8]) -> &mut Self {
        for &b in bytes {
            self.state ^= b as u64;
            self.state = self.state.wrapping_mul(FNV_PRIME);
        }
        self
    }

    /// Absorb a u64 as 8 little-endian bytes.
    pub fn update_u64(&mut self, v: u64) -> &mut Self {
        self.update(&v.to_le_bytes())
    }

    /// Absorb an f64 via its bit pattern (total, NaN-sensitive).
    pub fn update_f64(&mut self, v: f64) -> &mut Self {
        self.update_u64(v.to_bits())
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

/// One-shot FNV-1a of a byte slice.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.update(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn streaming_matches_oneshot() {
        let mut h = Fnv1a::new();
        h.update(b"foo").update(b"bar");
        assert_eq!(h.finish(), fnv1a(b"foobar"));
    }

    #[test]
    fn field_order_matters() {
        let mut a = Fnv1a::new();
        a.update_u64(1).update_u64(2);
        let mut b = Fnv1a::new();
        b.update_u64(2).update_u64(1);
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    fn f64_is_bit_exact() {
        let mut a = Fnv1a::new();
        a.update_f64(1.0);
        let mut b = Fnv1a::new();
        b.update_f64(1.0 + f64::EPSILON);
        assert_ne!(a.finish(), b.finish());
    }
}
