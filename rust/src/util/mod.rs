//! Small self-contained substrates the offline build denies us crates for:
//! JSON parsing, a stable hash, a seedable PRNG, a thread pool, a
//! property-testing mini-framework, and a benchmark timer.

pub mod bench;
pub mod hash;
pub mod json;
pub mod pool;
pub mod prng;
pub mod prop;
