//! Minimal recursive-descent JSON parser (offline build: no serde).
//!
//! Supports the full JSON grammar except `\u` surrogate pairs are mapped
//! through `char::from_u32` best-effort. Numbers parse as `f64`; helper
//! accessors convert to the integral types the manifest needs.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { s: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| n as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` access that tolerates missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
}

impl Json {
    /// Serialise back to compact JSON text.
    pub fn to_json_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.s[start..self.i]).map_err(|_| self.err("utf8"))?;
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                            code = code * 16 + d;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => {
                    // collect the full utf8 sequence starting at c
                    let len = utf8_len(c);
                    let start = self.i - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let s = std::str::from_utf8(&self.s[start..self.i])
                        .map_err(|_| self.err("utf8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(v.get("d"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn parses_unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn parses_utf8_passthrough() {
        let v = Json::parse(r#""héllo→""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo→"));
    }

    #[test]
    fn serializer_round_trips() {
        let src = r#"{"a": [1, 2.5, {"b": "c\n"}], "d": false, "e": null}"#;
        let v = Json::parse(src).unwrap();
        let text = v.to_json_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn serializer_escapes_controls() {
        let v = Json::Str("a\"b\\c\n\u{1}".into());
        let text = v.to_json_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }
}
