//! Property-testing mini-framework (no proptest offline).
//!
//! `forall(cases, seed, gen, check)` draws `cases` random inputs from
//! `gen`, runs `check`, and on failure performs greedy shrinking via the
//! input's `Shrink` implementation before reporting the minimal
//! counterexample. Deterministic for a fixed seed.

use super::prng::Rng;

/// Types that can propose strictly-smaller variants of themselves.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate smaller values; empty when fully shrunk.
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![0, self / 2, self - 1].into_iter().filter(|v| v < self).collect()
        }
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![0, self / 2, self - 1].into_iter().filter(|v| v < self).collect()
        }
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        for a in self.0.shrink() {
            out.push((a, self.1.clone()));
        }
        for b in self.1.shrink() {
            out.push((self.0.clone(), b));
        }
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink> Shrink for (A, B, C) {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        for a in self.0.shrink() {
            out.push((a, self.1.clone(), self.2.clone()));
        }
        for b in self.1.shrink() {
            out.push((self.0.clone(), b, self.2.clone()));
        }
        for c in self.2.shrink() {
            out.push((self.0.clone(), self.1.clone(), c));
        }
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // drop halves, drop one element, shrink one element
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        for i in 0..self.len().min(8) {
            let mut v = self.clone();
            v.remove(i);
            out.push(v);
        }
        for i in 0..self.len().min(8) {
            for s in self[i].shrink().into_iter().take(3) {
                let mut v = self.clone();
                v[i] = s;
                out.push(v);
            }
        }
        out
    }
}

/// Outcome of a property check.
pub type PropResult = Result<(), String>;

/// Run `check` on `cases` inputs drawn by `gen`; panic with the shrunk
/// counterexample on failure.
pub fn forall<T, G, F>(cases: usize, seed: u64, gen: G, check: F)
where
    T: Shrink,
    G: Fn(&mut Rng) -> T,
    F: Fn(&T) -> PropResult,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            let (min, min_msg, steps) = shrink_loop(input, msg, &check);
            panic!(
                "property failed (case {case}/{cases}, shrunk {steps} steps)\n\
                 counterexample: {min:?}\nfailure: {min_msg}"
            );
        }
    }
}

fn shrink_loop<T: Shrink, F: Fn(&T) -> PropResult>(
    mut cur: T,
    mut msg: String,
    check: &F,
) -> (T, String, usize) {
    let mut steps = 0;
    'outer: loop {
        if steps > 500 {
            break;
        }
        for cand in cur.shrink() {
            if let Err(m) = check(&cand) {
                cur = cand;
                msg = m;
                steps += 1;
                continue 'outer;
            }
        }
        break;
    }
    (cur, msg, steps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_true_property() {
        forall(200, 7, |r| r.below(100) as usize, |&x| {
            if x < 100 {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "counterexample: 10")]
    fn shrinks_to_minimal() {
        // fails for x >= 10; minimal counterexample is exactly 10
        forall(500, 7, |r| r.below(1000) as usize, |&x| {
            if x < 10 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }

    #[test]
    fn tuple_shrink_reduces_both() {
        let t = (8usize, 4usize);
        let cands = t.shrink();
        assert!(cands.iter().any(|&(a, _)| a < 8));
        assert!(cands.iter().any(|&(_, b)| b < 4));
    }

    #[test]
    fn vec_shrink_terminates() {
        let v: Vec<usize> = (0..20).collect();
        let mut cur = v;
        for _ in 0..1000 {
            match cur.shrink().into_iter().next() {
                Some(c) => cur = c,
                None => break,
            }
        }
        assert!(cur.is_empty());
    }
}
