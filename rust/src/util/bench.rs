//! Benchmark timing harness (no criterion offline).
//!
//! `Bench::run` warms up, then takes timed samples until a time budget
//! or sample cap is hit, and reports mean/median/p95/stddev. The bench
//! binaries in `rust/benches/` use it with `harness = false`.

use std::time::{Duration, Instant};

/// Summary statistics over timed samples.
#[derive(Debug, Clone)]
pub struct Stats {
    pub samples: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Stats {
    pub fn from_samples(mut ns: Vec<f64>) -> Stats {
        assert!(!ns.is_empty());
        // total_cmp, not partial_cmp().unwrap(): a NaN sample (e.g. a
        // poisoned timer diff) sorts deterministically after every
        // finite value instead of panicking mid-bench.
        ns.sort_by(|a, b| a.total_cmp(b));
        let n = ns.len();
        let mean = ns.iter().sum::<f64>() / n as f64;
        let var = ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Stats {
            samples: n,
            mean_ns: mean,
            median_ns: ns[n / 2],
            p95_ns: ns[((n as f64 * 0.95) as usize).min(n - 1)],
            stddev_ns: var.sqrt(),
            min_ns: ns[0],
            max_ns: ns[n - 1],
        }
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn human(&self) -> String {
        format!(
            "mean {} median {} p95 {} (±{}, n={})",
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.stddev_ns),
            self.samples
        )
    }
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// A named benchmark runner with warmup and budgets.
pub struct Bench {
    pub warmup_iters: usize,
    pub min_samples: usize,
    pub max_samples: usize,
    pub time_budget: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup_iters: 3,
            min_samples: 3,
            max_samples: 50,
            time_budget: Duration::from_secs(5),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            warmup_iters: 1,
            min_samples: 3,
            max_samples: 15,
            time_budget: Duration::from_secs(2),
        }
    }

    /// For expensive workloads (seconds per iteration): one sample
    /// unless the budget allows more.
    pub fn expensive() -> Self {
        Bench {
            warmup_iters: 0,
            min_samples: 1,
            max_samples: 3,
            time_budget: Duration::from_secs(8),
        }
    }

    /// Time `f` repeatedly; returns stats. `f`'s return is black-boxed.
    pub fn run<T, F: FnMut() -> T>(&self, mut f: F) -> Stats {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.max_samples);
        let start = Instant::now();
        while samples.len() < self.max_samples
            && (samples.len() < self.min_samples.max(1)
                || start.elapsed() < self.time_budget)
        {
            let t = Instant::now();
            black_box(f());
            samples.push(t.elapsed().as_nanos() as f64);
        }
        Stats::from_samples(samples)
    }
}

/// Prevent the optimizer from discarding a value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0, 100.0]);
        assert!(s.min_ns <= s.median_ns);
        assert!(s.median_ns <= s.max_ns);
        assert!(s.p95_ns <= s.max_ns);
        assert_eq!(s.samples, 5);
    }

    #[test]
    fn bench_runs_at_least_three_samples() {
        let b = Bench {
            warmup_iters: 0,
            min_samples: 3,
            max_samples: 10,
            time_budget: Duration::from_millis(1),
        };
        let s = b.run(|| std::thread::sleep(Duration::from_micros(50)));
        assert!(s.samples >= 3);
    }

    #[test]
    fn nan_samples_sort_last_instead_of_panicking() {
        // regression: from_samples used partial_cmp().unwrap(), which
        // panics on any NaN sample
        let s = Stats::from_samples(vec![3.0, f64::NAN, 1.0]);
        assert_eq!(s.samples, 3);
        assert_eq!(s.min_ns, 1.0);
        assert!(s.max_ns.is_nan(), "NaN must order after every finite sample");
        assert_eq!(s.median_ns, 3.0);
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert!(fmt_ns(1500.0).ends_with("µs"));
        assert!(fmt_ns(2.5e6).ends_with("ms"));
        assert!(fmt_ns(3.0e9).ends_with('s'));
    }
}
