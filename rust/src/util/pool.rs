//! Fixed-size thread pool over std channels (no tokio offline).
//!
//! The coordinator uses one pool for inference workers and the
//! auto-tuner uses one for parallel simulator sweeps. Jobs are boxed
//! closures; `scope_map` offers a convenience fork-join over a slice.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A fixed-size worker pool. Dropping it joins all workers.
pub struct ThreadPool {
    tx: Sender<Msg>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Msg>>> = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("ilpm-worker-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, workers }
    }

    /// Submit a fire-and-forget job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Fork-join map: apply `f` to every item on `pool`, preserving order.
pub fn pool_map<T, U, F>(pool: &ThreadPool, items: Vec<T>, f: F) -> Vec<U>
where
    T: Send + 'static,
    U: Send + 'static,
    F: Fn(T) -> U + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let (tx, rx) = channel::<(usize, U)>();
    let n = items.len();
    for (i, item) in items.into_iter().enumerate() {
        let tx = tx.clone();
        let f = Arc::clone(&f);
        pool.execute(move || {
            let _ = tx.send((i, f(item)));
        });
    }
    drop(tx);
    let mut out: Vec<Option<U>> = (0..n).map(|_| None).collect();
    for (i, v) in rx {
        out[i] = Some(v);
    }
    out.into_iter().map(|v| v.expect("worker panicked")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool_map(&pool, (0..64).collect(), |x: i32| x * 2);
        assert_eq!(out, (0..64).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }
}
