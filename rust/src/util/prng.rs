//! xoshiro256** — a small, fast, seedable PRNG (no `rand` crate offline).
//!
//! Used by the workload generators, the auto-tuner's random search, and
//! the property-testing harness. Deterministic across runs for a fixed
//! seed, which keeps tests and benches reproducible.

/// xoshiro256** state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so any u64 (including 0) yields a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_covers_all_residues() {
        let mut r = Rng::new(3);
        let mut seen = [false; 5];
        for _ in 0..1_000 {
            seen[r.below(5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
