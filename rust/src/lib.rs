//! ILP-M Conv — single-image CNN inference engine + mobile-GPU simulator.
//!
//! Reproduction of Ji, *"ILP-M Conv: Optimize Convolution Algorithm for
//! Single-Image Convolution Neural Network Inference on Mobile GPUs"*
//! (2019). Three-layer architecture:
//!
//! * **L1/L2** (build time, Python): Pallas convolution kernels for the
//!   five algorithms the paper evaluates + JAX ResNet graphs, AOT-lowered
//!   to HLO text under `artifacts/`.
//! * **L3** (this crate): the deployable system — a PJRT [`runtime`], a
//!   single-image inference [`coordinator`], the mobile-GPU
//!   microarchitecture [`simulator`] that reproduces the paper's
//!   evaluation (Figure 5, Tables 3–4), per-algorithm abstract-kernel
//!   trace generators in [`convgen`] (the paper's five plus a
//!   depthwise specialist for MobileNet's grouped layers), the network
//!   layer tables in [`workload`] (ResNet Table 2 and MobileNetV1 at
//!   width 1.0/0.5), the [`autotune`] search the paper's §5 describes,
//!   the persistent [`tunedb`] store that makes tuning results
//!   durable across processes (tune once per device, serve from disk
//!   forever), the [`fleet`] layer that serves open-loop traffic
//!   across many heterogeneous simulated devices with cost-aware
//!   dispatch and SLO admission control, the [`conformance`]
//!   suite that differentially verifies every lowering against the
//!   paper's closed-form accounting (`ilpm verify`), and the [`trace`]
//!   observability layer — deterministic virtual-clock span recording
//!   with Chrome-trace/tree exporters, a metrics registry, the
//!   `RUST_PALLAS_LOG` log facade, and the paper-style per-layer
//!   profile behind `ilpm profile`. The [`analysis`] module
//!   ("pallas-lint", `ilpm lint`) machine-checks the conventions all
//!   of the above rely on: virtual-clock-only time, `total_cmp`
//!   float ordering, sorted serialization, allocation-free hot paths.
//!
//! See README.md for the CLI front door, and DESIGN.md for the
//! paper→module map, the workload tables, the grouped-convolution
//! lowering rules, and the tunedb on-disk format and invalidation
//! rules.

pub mod analysis;
pub mod autotune;
pub mod cli;
pub mod conformance;
pub mod convgen;
pub mod coordinator;
pub mod fleet;
pub mod metrics;
pub mod runtime;
pub mod simulator;
pub mod trace;
pub mod tunedb;
pub mod util;
pub mod workload;
