//! End-to-end driver (DESIGN.md §E2E): serve single-image ResNet-18
//! inference requests through the full stack — request generator →
//! bounded queue → executor workers → PJRT-compiled AOT artifact —
//! and report latency/throughput. Results are recorded in
//! EXPERIMENTS.md.
//!
//! Flags: `--model <name>` (default resnet18_ref_r56; use
//! `resnet18_ilpm_r56` to push every 3x3 conv through the interpret-mode
//! ILP-M Pallas kernel — slow on CPU but exercises the L1 path),
//! `--n <requests>`, `--workers <N>`.
//!
//! Run: `cargo run --release --example resnet_inference`

use ilpm::cli::Args;
use ilpm::coordinator::InferenceEngine;
use ilpm::runtime::Manifest;
use ilpm::workload::{RequestGen, TraceKind};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let a = Args::parse(&argv, &["model", "n", "workers"]).map_err(anyhow::Error::msg)?;
    let model = a.get_or("model", "resnet18_ref_r56").to_string();
    let n = a.get_usize("n", 24).map_err(anyhow::Error::msg)?;
    let workers = a.get_usize("workers", 2).map_err(anyhow::Error::msg)?;

    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let manifest = Manifest::load(&dir)?;
    let art = manifest
        .find(&model)
        .ok_or_else(|| anyhow::anyhow!("model {model} not in manifest"))?;
    let img_shape = art.inputs[0].shape.clone();
    println!(
        "model={model} image={:?} params={} workers={workers} requests={n}",
        img_shape,
        art.inputs.len() - 1
    );

    let t0 = std::time::Instant::now();
    let engine = InferenceEngine::start_pjrt(&dir, &model, workers, 8)?;
    println!("engine ready in {:?} (compile + weight upload)", t0.elapsed());

    let mut gen = RequestGen::new(&img_shape, TraceKind::ClosedLoop, 7);
    let (summary, results) = engine.run_closed_loop(&mut gen, n)?;

    println!("\n=== end-to-end results ===");
    println!("total latency (incl. queueing): {summary}");
    let mut exec_ms: Vec<f64> =
        results.iter().map(|r| r.exec_latency.as_secs_f64() * 1e3).collect();
    exec_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "execution latency: p50={:.1}ms p95={:.1}ms",
        exec_ms[exec_ms.len() / 2],
        exec_ms[(exec_ms.len() as f64 * 0.95) as usize % exec_ms.len()]
    );
    let by_worker: Vec<usize> = (0..workers)
        .map(|w| results.iter().filter(|r| r.worker == w).count())
        .collect();
    println!("requests per worker: {by_worker:?}");
    let classes: Vec<usize> = results.iter().take(8).map(|r| r.class).collect();
    println!("first predicted classes: {classes:?}");
    anyhow::ensure!(
        results.iter().all(|r| r.logits.data.iter().all(|v| v.is_finite())),
        "non-finite logits"
    );
    // determinism across workers: same image id => same class
    let r0 = results.iter().find(|r| r.id == 0).unwrap();
    anyhow::ensure!(r0.logits.data.iter().all(|v| v.is_finite()));
    engine.shutdown();
    println!("resnet_inference OK");
    Ok(())
}
