//! Auto-tuning demo (paper §5): sweep each algorithm's kernel
//! parameters for one layer on the mobile-GPU model, print the chosen
//! configuration and the resulting per-layer ranking, and show the
//! routing table the inference engine would use per device.
//!
//! Run: `cargo run --release --example autotune_demo [--device mali]`

use ilpm::autotune::{tune, tune_all};
use ilpm::cli::Args;
use ilpm::convgen::Algorithm;
use ilpm::coordinator::RoutingTable;
use ilpm::simulator::DeviceConfig;
use ilpm::workload::{LayerClass, RESNET_DEPTHS};

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let a = Args::parse(&argv, &["device"]).map_err(anyhow::Error::msg)?;
    let dev = DeviceConfig::by_name(a.get_or("device", "mali"))
        .ok_or_else(|| anyhow::anyhow!("unknown device"))?;

    println!("=== tuning conv4.x on {} ===", dev.name);
    for alg in Algorithm::ALL {
        let e = tune(alg, LayerClass::Conv4x, &dev);
        println!(
            "{:>9}: {:>8.3} ms  ({} cfgs, {} pruned)  wg={} px_tile={} kpt={} cache={} m/n/k={}/{}/{} transpose={}",
            alg.name(),
            e.time_ms,
            e.stats.evaluated,
            e.stats.pruned,
            e.params.wg_size,
            e.params.tile_px,
            e.params.k_per_thread,
            e.params.cache_filters,
            e.params.tile_m,
            e.params.tile_n,
            e.params.tile_k,
            e.params.transpose_output,
        );
    }

    println!("\n=== full tuning sweep -> routing table ===");
    let db = tune_all(&[dev.clone()], 8);
    let table = RoutingTable::from_tuning(&db, dev.name);
    for layer in LayerClass::ALL {
        let r = table.route(layer).unwrap();
        println!(
            "{:<10} -> {:<9} (expected {:.3} ms/conv)",
            layer.name(),
            r.algorithm.name(),
            r.expected_ms
        );
    }

    println!("\n=== expected single-image 3x3-conv time per ResNet depth ===");
    for d in RESNET_DEPTHS {
        println!(
            "{:<10} {:>8.2} ms on {}",
            d.name,
            table.expected_network_ms(&d.convs),
            dev.name
        );
    }
    Ok(())
}
