//! Quickstart: load an AOT-compiled ILP-M convolution artifact, run it
//! through the PJRT runtime on a random single image, and verify the
//! numerics against the pure-Rust reference convolution.
//!
//! Run `make artifacts` first, then: `cargo run --release --example quickstart`

use ilpm::coordinator::naive_conv;
use ilpm::runtime::{Engine, Tensor};
use ilpm::workload::LayerClass;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    // 1. one PJRT CPU engine over the artifact directory
    let engine = Engine::new(&dir)?;
    println!("PJRT platform: {}", engine.platform());

    // 2. the paper's most-profiled layer: conv4.x (256x256, 14x14)
    let layer = LayerClass::Conv4x;
    let shape = layer.shape();
    let model = engine.load_layer(layer.name(), "ilpm")?;
    println!(
        "loaded {} (compiled in {:.0} ms)",
        model.artifact.name, model.compile_ms
    );

    // 3. single-image inference through the ILP-M kernel
    let x = Tensor::randn(&[shape.in_channels, shape.height, shape.width], 42);
    let w = Tensor::randn(
        &[shape.out_channels, shape.in_channels, shape.filter_h, shape.filter_w],
        43,
    );
    let t0 = std::time::Instant::now();
    let out = model.run(&[x.clone(), w.clone()])?;
    println!("executed in {:?}, output shape {:?}", t0.elapsed(), out[0].shape);

    // 4. verify against the independent Rust-side reference
    let expected = naive_conv(&shape, &x, &w);
    let diff = out[0].max_abs_diff(&expected)?;
    println!("max abs diff vs naive reference: {diff:.2e}");
    anyhow::ensure!(diff < 1e-2, "numerics diverged");
    println!("quickstart OK");
    Ok(())
}
