//! Profile explorer: the codeXL-style per-kernel profile (paper §5.2)
//! for any (device, layer), from tuned simulations — plus the ablations
//! DESIGN.md §6 calls out: the filter-caching variants of direct
//! convolution, ILP-M's output-transpose option, and a DRAM-bandwidth
//! sweep showing the im2col/libdnn crossover between device classes.
//!
//! Run: `cargo run --release --example profile_layers [--device vega8] [--layer conv4.x]`

use ilpm::cli::Args;
use ilpm::convgen::{generate, Algorithm, TuneParams};
use ilpm::metrics::{table3, table4};
use ilpm::simulator::{
    energy, simulate, simulate_pipeline, total_time_ms, DeviceConfig, EnergyModel,
};
use ilpm::workload::LayerClass;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let a = Args::parse(&argv, &["device", "layer"]).map_err(anyhow::Error::msg)?;
    let dev = DeviceConfig::by_name(a.get_or("device", "vega8"))
        .ok_or_else(|| anyhow::anyhow!("unknown device"))?;
    let layer = LayerClass::from_name(a.get_or("layer", "conv4.x"))
        .ok_or_else(|| anyhow::anyhow!("unknown layer"))?;
    let shape = layer.shape();

    println!("=== memory profile ({} on {}) ===", layer.name(), dev.name);
    print!("{}", table3(&dev, layer));
    println!("\n=== arithmetic profile ===");
    print!("{}", table4(&dev, layer));

    // ---- ablation 1: Algorithm 1's two variants ---------------------
    println!("\n=== ablation: direct conv filter caching (Algorithm 1) ===");
    for cache in [true, false] {
        let p = TuneParams { cache_filters: cache, ..TuneParams::for_shape(&shape) };
        let specs = generate(Algorithm::Direct, &shape, &p);
        let r = simulate(&specs[0], &dev);
        println!(
            "cache_filters={cache:<5} {:>8.3} ms  bound={:<8} barriers/wg={} memBusy={:.1}%",
            r.time_ms, r.bound, r.barriers_per_wg, r.mem_unit_busy_pct
        );
    }

    // ---- ablation 2: ILP-M output transpose -------------------------
    println!("\n=== ablation: ILP-M coalesced-store transpose (§4) ===");
    for transpose in [false, true] {
        let p = TuneParams { transpose_output: transpose, ..TuneParams::for_shape(&shape) };
        let specs = generate(Algorithm::Ilpm, &shape, &p);
        let r = simulate(&specs[0], &dev);
        println!(
            "transpose_output={transpose:<5} {:>8.3} ms  bound={:<8} smem/wg={}B",
            r.time_ms, r.bound, r.smem_per_wg
        );
    }

    // ---- extension: energy per conv (§2.2 quantified) ----------------
    println!("\n=== extension: energy per conv on {} (mJ) ===", dev.name);
    println!("(paper §2.2: off-chip access costs tens of times cache, hundreds of times a flop)");
    let emodel = EnergyModel::for_device(&dev);
    println!(
        "{:<10} {:>9} {:>9} {:>9} {:>9} {:>9} {:>11}",
        "algorithm", "compute", "dram", "l2", "smem", "total", "dram-share"
    );
    for alg in Algorithm::ALL {
        let p = TuneParams::paper_profile(alg);
        let specs = generate(alg, &shape, &p);
        let mut acc = (0.0, 0.0, 0.0, 0.0, 0.0, 0.0);
        for (i, s) in specs.iter().enumerate() {
            let r = simulate(s, &dev);
            // attribute the conv's useful FLOPs to the main kernel
            let flops = if i == specs.len() - 1 { shape.flops() as f64 } else { 0.0 };
            let e = energy(&r, flops, &dev, &emodel);
            acc.0 += e.compute_mj;
            acc.1 += e.dram_mj;
            acc.2 += e.l2_mj;
            acc.3 += e.smem_mj;
            acc.4 += e.total_mj();
            acc.5 += e.dram_mj; // for the share below
        }
        let dynamic = acc.0 + acc.1 + acc.2 + acc.3;
        println!(
            "{:<10} {:>9.3} {:>9.3} {:>9.3} {:>9.3} {:>9.3} {:>10.0}%",
            alg.name(),
            acc.0,
            acc.1,
            acc.2,
            acc.3,
            acc.4,
            if dynamic > 0.0 { acc.1 / dynamic * 100.0 } else { 0.0 }
        );
    }

    // ---- ablation 3: bandwidth sweep (im2col vs libdnn crossover) ---
    println!("\n=== ablation: DRAM bandwidth sweep, im2col vs libdnn ===");
    println!("(paper §5.1: libdnn wins on bandwidth-starved devices, loses on HBM2)");
    let p = TuneParams::for_shape(&shape);
    for bw_gbs in [15.0, 25.0, 33.3, 100.0, 300.0, 1024.0] {
        let mut d = DeviceConfig::radeon_vii(); // fix compute, vary DRAM
        d.dram_bw_bytes_per_s = bw_gbs * 1e9;
        let im2col =
            total_time_ms(&simulate_pipeline(&generate(Algorithm::Im2col, &shape, &p), &d));
        let libdnn =
            total_time_ms(&simulate_pipeline(&generate(Algorithm::Libdnn, &shape, &p), &d));
        println!(
            "bw={bw_gbs:>7.1} GB/s  im2col={im2col:>8.3} ms  libdnn={libdnn:>8.3} ms  winner={}",
            if libdnn < im2col { "libdnn" } else { "im2col" }
        );
    }
    Ok(())
}
